//! Parallel simulation drivers (PDES): conservative epochs and
//! Chandy-Misra-Bryant null messages, with deterministic load-balanced
//! sharding.
//!
//! The engine shards by tile: each worker thread owns a contiguous
//! block of tiles ([`TilePartition`]) — cores, their co-located
//! LLC/TM slices, and the memory controllers homed there — with a
//! private event queue and message slab.  Two synchronization modes
//! drive the shards ([`PdesMode`], DESIGN.md §11.5):
//!
//! * **Epoch** (PR-8): workers advance in lockstep windows of width
//!   `L` = the global minimum cross-shard latency
//!   ([`LookaheadTable::min`]).  Every event dispatched in `[T, T+L)`
//!   schedules cross-shard work at `>= T+L`, so mail exchanged at the
//!   two epoch barriers always lands in a future window.  Cheap per
//!   epoch, but the single tightest shard boundary rate-limits every
//!   shard.
//!
//! * **NullMsg**: classic CMB per-edge channel clocks.  After each
//!   dispatch window a shard publishes, per outbound neighbor `j`, a
//!   promise `clock[me][j] = min(next_fire, safe) + L(me, j)` — a
//!   *null message* when no real mail was sent — and independently
//!   advances to `safe = min_j clock[j][me]`.  Shards separated by
//!   wide windows no longer wait on the globally tightest edge.
//!
//! Every `rebalance_every` lookahead windows the drivers may
//! repartition tiles by *simulated* cumulative per-tile event counts
//! ([`TilePartition::from_counts`]) — never host timings — migrating
//! each moved tile's full state ([`TileMigration`]).  Because the
//! weights and the cut cycle are pure simulated quantities, the
//! decision sequence is identical across runs and thread schedules
//! (DESIGN.md §11.6).
//!
//! Determinism is bit-for-bit in both modes: every push carries a
//! canonical [`PushKey`] minted by the *sending* reactor, identical
//! in serial and sharded runs, and per-shard queues pop in global
//! `(cycle, key)` order restricted to the shard.  Since shards
//! partition the reactors and a reactor's dispatch sequence fully
//! determines its state, an N-thread run produces the same per-shard
//! stats — merged with commutative sums — and the same access log —
//! merged by sorting per-dispatch record groups on `(cycle, key)` —
//! as the 1-thread run.  `tests/determinism.rs` asserts exactly this.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::api::observer::Observers;
use crate::config::{PdesMode, SystemConfig};
use crate::net::{Message, Topology};
use crate::obs::{ExecEvent, ExecKind, TraceRecording, TRACE_CAP};
use crate::prog::checker::AccessLog;
use crate::prog::Workload;
use crate::stats::{ParallelStats, ShardLoad, SimStats};
use crate::types::Cycle;

use super::engine::{Engine, ShardSpec, SimResult, TileMigration, TilePartition};
use super::event::{Event, PushKey};

/// Per-(src shard, dst shard) conservative windows: `get(i, j)` is the
/// minimum fabric latency from any tile of shard `i` to any tile of
/// shard `j`, probed with a 1-flit control message (latency grows with
/// flit count, so the control probe is the true minimum).  On NUMA
/// fabrics the matrix has the interesting asymmetry: intra-socket
/// shard pairs get tight mesh windows while cross-socket pairs get the
/// wide link window — exactly the spread null-message mode exploits.
pub(crate) struct LookaheadTable {
    count: u32,
    /// Global minimum over all cross-shard pairs (the epoch window
    /// width).  Always >= 1: distinct shards occupy distinct tiles.
    pub min: Cycle,
    m: Vec<Cycle>,
}

impl LookaheadTable {
    pub(crate) fn get(&self, src: u32, dst: u32) -> Cycle {
        self.m[(src * self.count + dst) as usize]
    }
}

/// Build the lookahead matrix for `part`.  Route timing depends only
/// on the endpoint tiles and flit count, so probing tile pairs covers
/// every node kind (core, slice, MC) homed on them.
pub(crate) fn lookahead_table(cfg: &SystemConfig, part: &TilePartition) -> LookaheadTable {
    let topo = Topology::new(cfg);
    let count = part.count();
    let mut m = vec![Cycle::MAX; (count as usize) * (count as usize)];
    for src in 0..count {
        let (slo, shi) = part.range(src);
        for dst in 0..count {
            if src == dst {
                continue;
            }
            let (dlo, dhi) = part.range(dst);
            let mut min = Cycle::MAX;
            for a in slo..shi {
                for b in dlo..dhi {
                    min = min.min(topo.probe_latency(a, b));
                }
            }
            m[(src * count + dst) as usize] = min;
        }
    }
    let min = m.iter().copied().filter(|&x| x != Cycle::MAX).min().unwrap_or(Cycle::MAX);
    LookaheadTable { count, min, m }
}

/// The global conservative lookahead for `shards` balanced shards of
/// `cfg` — the epoch window width (the scalar face of the matrix).
pub(crate) fn lookahead(cfg: &SystemConfig, shards: u32) -> Cycle {
    lookahead_table(cfg, &TilePartition::balanced(cfg.n_cores, shards)).min
}

/// Resolve `Auto`: null messages pay off when the global minimum
/// window is small relative to the per-edge windows (the matrix has
/// spread, so most shard pairs could run far ahead of the epoch
/// width).  When the matrix is uniform — e.g. shards == sockets, every
/// cross-shard route crossing the same link — epochs already advance
/// every shard at the per-edge bound and two barriers are cheaper
/// than per-edge clock maintenance.
fn resolve_mode(mode: PdesMode, table: &LookaheadTable) -> PdesMode {
    match mode {
        PdesMode::Auto => {
            let offs: Vec<Cycle> = table.m.iter().copied().filter(|&x| x != Cycle::MAX).collect();
            let sum: u128 = offs.iter().map(|&x| x as u128).sum();
            let mean = sum as f64 / offs.len().max(1) as f64;
            if (table.min as f64) * 2.0 < mean {
                PdesMode::NullMsg
            } else {
                PdesMode::Epoch
            }
        }
        m => m,
    }
}

/// Post-injection shard state published at each epoch's second
/// barrier; every worker reads all slots and derives the same verdict.
#[derive(Default)]
struct ShardStatus {
    next_fire: Option<Cycle>,
    finished: u32,
    error: Option<String>,
}

struct WorkerDone {
    out: super::engine::ShardOutput,
    load: ShardLoad,
    epochs: u64,
    /// Host-side window/rebalance markers (traced runs only).
    exec: Vec<ExecEvent>,
}

/// Per-shard cap on host-side exec markers: window boundaries can
/// number in the millions on long runs; the first few thousand are
/// plenty for a host timeline.
const EXEC_CAP: usize = 4096;

type Mailbox = Mutex<Vec<(Cycle, PushKey, Message)>>;

/// Shared state of an epoch-mode run.
struct EpochShared {
    statuses: Vec<Mutex<ShardStatus>>,
    /// `mailboxes[to][from]`: senders fill before barrier A, the owner
    /// drains between barriers A and B.
    mailboxes: Vec<Vec<Mailbox>>,
    barrier: Barrier,
    /// Cumulative per-tile event counts, published at barrier C; every
    /// rebalance rewrites all entries (shard ranges partition tiles).
    counts: Mutex<Vec<u64>>,
    /// Indexed by tile: the old owner stashes before barrier D, the
    /// new owner takes after it.
    migrations: Vec<Mutex<Option<TileMigration>>>,
    rebalances: AtomicU64,
    migrated: AtomicU64,
}

/// Shared state of a null-message run: one mutex, one condvar.  All
/// cross-shard coordination — channel clocks, mail, rendezvous
/// phases — lives under the single lock, so every predicate a worker
/// evaluates is a consistent snapshot.
struct Cmb {
    mu: Mutex<CmbShared>,
    cv: Condvar,
}

struct CmbShared {
    /// Channel clocks, `clock[src * n + dst]`: a promise that no
    /// message from `src` will be delivered to `dst` below this cycle.
    /// Monotone non-decreasing within a rebalance generation; reset to
    /// `ck + L_new` at a rendezvous (sound: nobody dispatched past
    /// `ck`, so overshoot promises were never consumed).
    clock: Vec<Cycle>,
    /// Published earliest pending event per shard (`None` = drained).
    next_fire: Vec<Option<Cycle>>,
    finished: Vec<u32>,
    /// `mail[dst][src]`, pushed atomically with the sender's clock
    /// update — the CMB no-time-travel invariant.
    mail: Vec<Vec<Vec<(Cycle, PushKey, Message)>>>,
    done: bool,
    error: Option<String>,
    la: LookaheadTable,
    /// Next rebalance checkpoint cycle (`Cycle::MAX` = rebalancing
    /// off).  All shards drain strictly below `ck`, rendezvous, then
    /// `ck` advances — a deterministic simulated cut.
    ck: Cycle,
    /// Rendezvous phase: 0 running, 1 counts, 2 extract, 3 install.
    phase: u8,
    arrived: u32,
    /// Rendezvous generation, bumped at each completion.
    gen: u64,
    counts: Vec<u64>,
    staged: Option<TilePartition>,
    migrations: Vec<Option<TileMigration>>,
    null_msgs: u64,
    rebalances: u64,
    migrated: u64,
}

/// Run `cfg` + `workload` across `threads` shards and merge the
/// results into the same `SimResult` the serial engine produces.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_parallel(
    cfg: SystemConfig,
    workload: &Workload,
    threads: u32,
    record_log: bool,
    record_trace: bool,
    mode: PdesMode,
    rebalance_every: u32,
) -> Result<SimResult> {
    assert!(threads >= 2, "run_parallel needs at least two shards");
    let part0 = TilePartition::balanced(cfg.n_cores, threads);
    let table = lookahead_table(&cfg, &part0);
    if table.min == 0 || table.min == Cycle::MAX {
        bail!("degenerate lookahead for {threads} shards (is the system shardable?)");
    }
    let la_min = table.min;
    let mode = resolve_mode(mode, &table);
    let n = threads as usize;
    let n_cores = cfg.n_cores;
    let t0 = Instant::now();
    let (results, null_msgs, rebalances, migrated) = match mode {
        PdesMode::Epoch => {
            run_epoch(&cfg, workload, threads, record_log, record_trace, rebalance_every, la_min)
        }
        PdesMode::NullMsg => {
            run_nullmsg(&cfg, workload, threads, record_log, record_trace, rebalance_every, table)
        }
        PdesMode::Auto => unreachable!("Auto resolved above"),
    };

    let mut outs = Vec::with_capacity(n);
    let mut loads = Vec::with_capacity(n);
    let mut exec_all: Vec<ExecEvent> = Vec::new();
    let mut epochs = 0u64;
    let mut errs: Vec<String> = Vec::new();
    for r in results {
        match r {
            Ok(d) => {
                epochs = epochs.max(d.epochs);
                loads.push(d.load);
                exec_all.extend(d.exec);
                outs.push(d.out);
            }
            Err(e) => errs.push(e),
        }
    }
    if !errs.is_empty() {
        errs.dedup();
        bail!("{}", errs.join("\n"));
    }
    let wall_ns = (t0.elapsed().as_nanos() as u64).max(1);

    let global_last = outs.iter().map(|o| o.last_now).max().unwrap_or(0);
    let mut core_finish = vec![global_last; n_cores as usize];
    let mut stats = SimStats { n_cores, ..SimStats::default() };
    for o in &outs {
        stats.absorb(&o.stats);
        for &(c, t) in &o.core_finish {
            core_finish[c as usize] = t;
        }
    }
    stats.cycles = core_finish.iter().copied().max().unwrap_or(0);
    stats.parallel = ParallelStats {
        threads,
        lookahead: la_min,
        epochs,
        wall_ns,
        null_msgs,
        rebalances,
        migrated_events: migrated,
        shards: loads,
    };

    // Canonical log merge: per-dispatch record groups, globally sorted
    // by the dispatched event's (cycle, key) — the exact order the
    // serial engine dispatched them in — then re-sequenced, because
    // serial `seq` is positional (1-based commit order).
    let mut order: Vec<(Cycle, PushKey, usize, u32, u32)> = Vec::new();
    for (i, o) in outs.iter().enumerate() {
        for &(cy, key, start, end) in &o.log_groups {
            order.push((cy, key, i, start, end));
        }
    }
    order.sort_unstable_by_key(|&(cy, key, ..)| (cy, key));
    let mut log = AccessLog::default();
    log.records.reserve(outs.iter().map(|o| o.log.records.len()).sum());
    for &(_, _, i, start, end) in &order {
        log.records.extend_from_slice(&outs[i].log.records[start as usize..end as usize]);
    }
    for (i, r) in log.records.iter_mut().enumerate() {
        r.seq = (i + 1) as u64;
    }

    // Canonical trace merge: the identical mechanism.  Each shard's
    // kept events are a prefix of its local sequence, so re-sorting
    // the per-dispatch groups by the dispatched event's (cycle, key)
    // and truncating to the same global cap reproduces the serial
    // recording bit for bit (DESIGN.md §12).
    let mut trace = TraceRecording::default();
    if record_trace {
        trace.enabled = true;
        let mut torder: Vec<(Cycle, PushKey, usize, u32, u32)> = Vec::new();
        for (i, o) in outs.iter().enumerate() {
            for &(cy, key, start, end) in &o.trace_groups {
                torder.push((cy, key, i, start, end));
            }
        }
        torder.sort_unstable_by_key(|&(cy, key, ..)| (cy, key));
        trace.events.reserve(outs.iter().map(|o| o.trace_events.len()).sum());
        for &(_, _, i, start, end) in &torder {
            trace.events.extend_from_slice(&outs[i].trace_events[start as usize..end as usize]);
        }
        trace.events.truncate(TRACE_CAP);
        let emitted: u64 = outs.iter().map(|o| o.trace_emitted).sum();
        trace.dropped = emitted - trace.events.len() as u64;
        exec_all.sort_unstable_by_key(|e| (e.cycle, e.shard, e.kind as u8, e.arg));
        trace.exec = exec_all;
    }

    Ok(SimResult { stats, log, core_finish, trace })
}

// ---------------------------------------------------------------------------
// Shared rebalance machinery
// ---------------------------------------------------------------------------

/// Drain this shard's queue, keep events for tiles it retains under
/// `new`, and package each lost tile through `stash`.  Valid only at a
/// rebalance cut: all pending events fire at or beyond it, so the
/// snapshot is cut-point consistent.
fn extract_lost_tiles(
    eng: &mut Engine,
    old: &TilePartition,
    new: &TilePartition,
    me: u32,
    workload: &Workload,
    mut stash: impl FnMut(u32, TileMigration),
) -> Vec<(Cycle, PushKey, Event)> {
    let (olo, ohi) = old.range(me);
    let (nlo, nhi) = new.range(me);
    let drained = eng.drain_events();
    let mut keeps = Vec::with_capacity(drained.len());
    let mut buckets: Vec<Vec<(Cycle, PushKey, Event)>> = (olo..ohi).map(|_| Vec::new()).collect();
    for (at, key, ev) in drained {
        let tile = eng.event_tile(&ev);
        debug_assert!(tile >= olo && tile < ohi, "shard queue held a foreign event");
        if tile >= nlo && tile < nhi {
            keeps.push((at, key, ev));
        } else {
            buckets[(tile - olo) as usize].push((at, key, ev));
        }
    }
    for tile in olo..ohi {
        if tile >= nlo && tile < nhi {
            continue;
        }
        let evs = std::mem::take(&mut buckets[(tile - olo) as usize]);
        stash(tile, eng.extract_tile(tile, evs, workload));
    }
    keeps
}

/// Adopt `new`, install every gained tile fetched through `fetch`,
/// and re-push kept + gained events in one sorted pass (the first
/// push rewinds the drained queue's cursor; sorted order keeps every
/// later push at or beyond it).  Returns the number of pending events
/// that migrated in.
fn install_gained_tiles(
    eng: &mut Engine,
    old: &TilePartition,
    new: &TilePartition,
    me: u32,
    mut keeps: Vec<(Cycle, PushKey, Event)>,
    mut fetch: impl FnMut(u32) -> TileMigration,
) -> u64 {
    eng.set_partition(new);
    let (olo, ohi) = old.range(me);
    let (nlo, nhi) = new.range(me);
    let mut moved = 0u64;
    for tile in nlo..nhi {
        if tile >= olo && tile < ohi {
            continue;
        }
        let m = fetch(tile);
        moved += m.events.len() as u64;
        keeps.extend(eng.install_tile(m));
    }
    keeps.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    eng.push_events(keeps);
    moved
}

// ---------------------------------------------------------------------------
// Epoch mode
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn run_epoch(
    cfg: &SystemConfig,
    workload: &Workload,
    threads: u32,
    record_log: bool,
    record_trace: bool,
    rebalance_every: u32,
    la: Cycle,
) -> (Vec<std::result::Result<WorkerDone, String>>, u64, u64, u64) {
    let n = threads as usize;
    let shared = EpochShared {
        statuses: (0..n).map(|_| Mutex::new(ShardStatus::default())).collect(),
        mailboxes: (0..n).map(|_| (0..n).map(|_| Mutex::new(Vec::new())).collect()).collect(),
        barrier: Barrier::new(n),
        counts: Mutex::new(vec![0; cfg.n_cores as usize]),
        migrations: (0..cfg.n_cores).map(|_| Mutex::new(None)).collect(),
        rebalances: AtomicU64::new(0),
        migrated: AtomicU64::new(0),
    };
    let results: Vec<std::result::Result<WorkerDone, String>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|me| {
                let shared = &shared;
                s.spawn(move || {
                    run_shard_epoch(
                        cfg,
                        workload,
                        me,
                        threads,
                        la,
                        record_log,
                        record_trace,
                        rebalance_every,
                        shared,
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard thread panicked")).collect()
    });
    let rb = shared.rebalances.into_inner();
    let mig = shared.migrated.into_inner();
    (results, 0, rb, mig)
}

#[allow(clippy::too_many_arguments)]
fn run_shard_epoch(
    cfg: &SystemConfig,
    workload: &Workload,
    me: u32,
    threads: u32,
    la0: Cycle,
    record_log: bool,
    record_trace: bool,
    rebalance_every: u32,
    sh: &EpochShared,
) -> std::result::Result<WorkerDone, String> {
    let n_cores = cfg.n_cores;
    let obs = if record_log { Observers::with_sc_log() } else { Observers::none() };
    let mut eng =
        Engine::build_shard(cfg.clone(), workload, obs, ShardSpec { index: me, count: threads });
    if record_trace {
        eng.enable_trace();
    }
    eng.seed();
    let mut part = TilePartition::balanced(cfg.n_cores, threads);
    let mut la = la0;
    let mut window_start: Cycle = 0;
    let mut epochs: u64 = 0;
    let mut busy_ns: u64 = 0;
    let mut wait_ns: u64 = 0;
    let mut exec: Vec<ExecEvent> = Vec::new();
    let verdict: std::result::Result<(), String> = loop {
        epochs += 1;
        if record_trace && exec.len() < EXEC_CAP {
            exec.push(ExecEvent {
                kind: ExecKind::Window,
                cycle: window_start,
                shard: me,
                arg: epochs,
            });
        }
        let limit = window_start.saturating_add(la);
        let b0 = Instant::now();
        let res = eng.run_window(limit).map_err(|e| format!("{e:#}"));
        if res.is_ok() {
            for dest in 0..threads {
                if dest == me {
                    continue;
                }
                let out = eng.take_outbox(dest);
                if !out.is_empty() {
                    sh.mailboxes[dest as usize][me as usize].lock().unwrap().extend(out);
                }
            }
        }
        busy_ns += b0.elapsed().as_nanos() as u64;
        let w0 = Instant::now();
        sh.barrier.wait(); // A: every shard's outboxes are published.
        wait_ns += w0.elapsed().as_nanos() as u64;

        let b1 = Instant::now();
        let mut err = res.err();
        if err.is_none() {
            for src in 0..threads {
                if src == me {
                    continue;
                }
                let mail =
                    std::mem::take(&mut *sh.mailboxes[me as usize][src as usize].lock().unwrap());
                for (at, key, msg) in mail {
                    eng.inject(at, key, msg);
                }
            }
        }
        {
            let mut st = sh.statuses[me as usize].lock().unwrap();
            st.next_fire = eng.next_fire();
            st.finished = eng.finished_cores();
            st.error = err.take();
        }
        busy_ns += b1.elapsed().as_nanos() as u64;
        let w1 = Instant::now();
        sh.barrier.wait(); // B: every shard's post-injection status is visible.
        wait_ns += w1.elapsed().as_nanos() as u64;

        // Symmetric decision: all workers read the same snapshot (the
        // slots can't be rewritten until every reader passes the next
        // barrier A) and derive the same verdict — no coordinator.
        let mut min_next: Option<Cycle> = None;
        let mut finished_total = 0u32;
        let mut error: Option<String> = None;
        for st in &sh.statuses {
            let st = st.lock().unwrap();
            if let Some(t) = st.next_fire {
                min_next = Some(min_next.map_or(t, |m: Cycle| m.min(t)));
            }
            finished_total += st.finished;
            if error.is_none() {
                error.clone_from(&st.error);
            }
        }
        if let Some(e) = error {
            break Err(e);
        }
        match min_next {
            // Every queue drained and every core done: quiescence,
            // matching the serial engine's drain-to-quiescence exit.
            None if finished_total == n_cores => break Ok(()),
            None => {
                let stuck = eng.stuck_cores().join("\n");
                break Err(format!(
                    "deadlock: all shards drained with {finished_total}/{n_cores} cores \
                     finished\nshard {me} stuck cores:\n{stuck}"
                ));
            }
            Some(t) => {
                // Conservative soundness: the earliest pending event
                // anywhere is at or past this window's end (locals
                // below `limit` were dispatched; cross-shard fires are
                // >= now + la >= limit).
                debug_assert!(t >= limit, "event at {t} fired inside closed window [.., {limit})");
                // Deterministic rebalance point: every worker counts
                // the same epochs and reads the same decision, so all
                // trigger together.  Mailboxes and outboxes are
                // provably empty here and every pending event fires at
                // or beyond `t` — a consistent cut (DESIGN.md §11.6).
                if rebalance_every > 0 && epochs % rebalance_every as u64 == 0 {
                    let b2 = Instant::now();
                    {
                        let mut counts = sh.counts.lock().unwrap();
                        let (lo, hi) = part.range(me);
                        let mine = eng.tile_counts();
                        for tile in lo..hi {
                            counts[tile as usize] = mine[tile as usize];
                        }
                    }
                    busy_ns += b2.elapsed().as_nanos() as u64;
                    let w2 = Instant::now();
                    sh.barrier.wait(); // C: all cumulative tile counts published.
                    wait_ns += w2.elapsed().as_nanos() as u64;
                    let b3 = Instant::now();
                    let new_part = TilePartition::from_counts(&sh.counts.lock().unwrap(), threads);
                    if new_part != part {
                        let keeps =
                            extract_lost_tiles(&mut eng, &part, &new_part, me, workload, |t, m| {
                                *sh.migrations[t as usize].lock().unwrap() = Some(m)
                            });
                        busy_ns += b3.elapsed().as_nanos() as u64;
                        let w3 = Instant::now();
                        sh.barrier.wait(); // D: all lost tiles stashed.
                        wait_ns += w3.elapsed().as_nanos() as u64;
                        let b4 = Instant::now();
                        let moved = install_gained_tiles(&mut eng, &part, &new_part, me, keeps, |t| {
                            sh.migrations[t as usize]
                                .lock()
                                .unwrap()
                                .take()
                                .expect("old owner stashed the tile before barrier D")
                        });
                        if moved > 0 {
                            sh.migrated.fetch_add(moved, Ordering::Relaxed);
                        }
                        if record_trace && exec.len() < EXEC_CAP {
                            exec.push(ExecEvent {
                                kind: ExecKind::Rebalance,
                                cycle: t,
                                shard: me,
                                arg: moved,
                            });
                        }
                        la = lookahead_table(cfg, &new_part).min;
                        part = new_part;
                        if me == 0 {
                            sh.rebalances.fetch_add(1, Ordering::Relaxed);
                        }
                        busy_ns += b4.elapsed().as_nanos() as u64;
                        let w4 = Instant::now();
                        sh.barrier.wait(); // E: all gained tiles installed.
                        wait_ns += w4.elapsed().as_nanos() as u64;
                    } else {
                        busy_ns += b3.elapsed().as_nanos() as u64;
                    }
                }
                window_start = t;
            }
        }
    };
    verdict?;
    let out = eng.finalize_shard();
    let load = ShardLoad { shard: me, events: out.stats.events, busy_ns, wait_ns };
    Ok(WorkerDone { out, load, epochs, exec })
}

// ---------------------------------------------------------------------------
// Null-message mode
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn run_nullmsg(
    cfg: &SystemConfig,
    workload: &Workload,
    threads: u32,
    record_log: bool,
    record_trace: bool,
    rebalance_every: u32,
    table: LookaheadTable,
) -> (Vec<std::result::Result<WorkerDone, String>>, u64, u64, u64) {
    let n = threads as usize;
    let ck = if rebalance_every == 0 {
        Cycle::MAX
    } else {
        (rebalance_every as Cycle).saturating_mul(table.min)
    };
    let shared = Cmb {
        mu: Mutex::new(CmbShared {
            clock: vec![0; n * n],
            next_fire: vec![Some(0); n],
            finished: vec![0; n],
            mail: (0..n).map(|_| (0..n).map(|_| Vec::new()).collect()).collect(),
            done: false,
            error: None,
            la: table,
            ck,
            phase: 0,
            arrived: 0,
            gen: 0,
            counts: vec![0; cfg.n_cores as usize],
            staged: None,
            migrations: (0..cfg.n_cores).map(|_| None).collect(),
            null_msgs: 0,
            rebalances: 0,
            migrated: 0,
        }),
        cv: Condvar::new(),
    };
    let results: Vec<std::result::Result<WorkerDone, String>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|me| {
                let shared = &shared;
                s.spawn(move || {
                    run_shard_nullmsg(
                        cfg,
                        workload,
                        me,
                        threads,
                        record_log,
                        record_trace,
                        rebalance_every,
                        shared,
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard thread panicked")).collect()
    });
    let guard = shared.mu.lock().unwrap();
    (results, guard.null_msgs, guard.rebalances, guard.migrated)
}

/// Minimum inbound channel clock of shard `me`: nothing can be
/// delivered to it below this bound.
fn inbound_bound(sh: &CmbShared, me: usize, n: usize) -> Cycle {
    let mut safe = Cycle::MAX;
    for j in 0..n {
        if j != me {
            safe = safe.min(sh.clock[j * n + me]);
        }
    }
    safe
}

/// Publish shard `me`'s state: advance its clock row to
/// `min(next_fire, safe) + L(me, j)` (monotone — the old promise stays
/// valid because every future dispatch is at or beyond the old floor),
/// and refresh its `next_fire`/`finished` slots.  An edge whose clock
/// advances without real mail (`sent_real[j]` false) is a null
/// message.  Returns whether anything changed (callers notify).
fn publish(sh: &mut CmbShared, eng: &Engine, me: usize, n: usize, sent_real: &[bool]) -> bool {
    let safe = inbound_bound(sh, me, n);
    let nf = eng.next_fire();
    let floor = nf.unwrap_or(Cycle::MAX).min(safe);
    let mut changed = false;
    for j in 0..n {
        if j == me {
            continue;
        }
        let promise = floor.saturating_add(sh.la.get(me as u32, j as u32));
        if promise > sh.clock[me * n + j] {
            sh.clock[me * n + j] = promise;
            changed = true;
            if !sent_real[j] {
                sh.null_msgs += 1;
            }
        }
    }
    if sh.next_fire[me] != nf {
        sh.next_fire[me] = nf;
        changed = true;
    }
    let fin = eng.finished_cores();
    if sh.finished[me] != fin {
        sh.finished[me] = fin;
        changed = true;
    }
    changed
}

#[allow(clippy::too_many_arguments)]
fn run_shard_nullmsg(
    cfg: &SystemConfig,
    workload: &Workload,
    me: u32,
    threads: u32,
    record_log: bool,
    record_trace: bool,
    rebalance_every: u32,
    shared: &Cmb,
) -> std::result::Result<WorkerDone, String> {
    let n = threads as usize;
    let n_cores = cfg.n_cores;
    let obs = if record_log { Observers::with_sc_log() } else { Observers::none() };
    let mut eng =
        Engine::build_shard(cfg.clone(), workload, obs, ShardSpec { index: me, count: threads });
    if record_trace {
        eng.enable_trace();
    }
    eng.seed();
    let mut part = TilePartition::balanced(cfg.n_cores, threads);
    let mut rounds: u64 = 0;
    let mut busy_ns: u64 = 0;
    let mut wait_ns: u64 = 0;
    let mut exec: Vec<ExecEvent> = Vec::new();
    let no_real = vec![false; n];
    let verdict: std::result::Result<(), String> = 'run: loop {
        // --- sync step: drain mail, publish, decide (one lock) ---
        let limit = {
            let mut sh = shared.mu.lock().unwrap();
            let mut mark = Instant::now();
            let decision: Option<Cycle> = 'decide: loop {
                if sh.done {
                    break 'decide None;
                }
                let mut changed = false;
                for src in 0..n {
                    let mail = std::mem::take(&mut sh.mail[me as usize][src]);
                    if !mail.is_empty() {
                        changed = true;
                        for (at, key, msg) in mail {
                            eng.inject(at, key, msg);
                        }
                    }
                }
                changed |= publish(&mut sh, &eng, me as usize, n, &no_real);
                let limit = inbound_bound(&sh, me as usize, n).min(sh.ck);
                if eng.next_fire().map_or(false, |t| t < limit) {
                    if changed {
                        shared.cv.notify_all();
                    }
                    break 'decide Some(limit);
                }
                let mail_empty = sh.mail.iter().all(|row| row.iter().all(|v| v.is_empty()));
                if mail_empty && sh.next_fire.iter().all(|f| f.is_none()) {
                    // Global quiescence (or deadlock — decided below).
                    sh.done = true;
                    shared.cv.notify_all();
                    break 'decide None;
                }
                // Rebalance rendezvous: everyone has drained strictly
                // below `ck` and no mail is in flight.  Stable (each
                // dispatch limit is clamped to `ck`) and race-free (a
                // mid-window worker's published next_fire is < ck, and
                // mail is drained atomically with the next_fire
                // refresh, so the predicate never sees a stale gap).
                if sh.ck < Cycle::MAX
                    && mail_empty
                    && sh.next_fire.iter().all(|f| f.map_or(true, |t| t >= sh.ck))
                {
                    sh = rendezvous(
                        sh,
                        &shared.cv,
                        &mut eng,
                        &mut part,
                        me,
                        n,
                        workload,
                        cfg,
                        rebalance_every,
                        if record_trace { Some(&mut exec) } else { None },
                    );
                    continue 'decide;
                }
                if changed {
                    shared.cv.notify_all();
                }
                busy_ns += mark.elapsed().as_nanos() as u64;
                let w0 = Instant::now();
                sh = shared.cv.wait(sh).unwrap();
                wait_ns += w0.elapsed().as_nanos() as u64;
                mark = Instant::now();
            };
            busy_ns += mark.elapsed().as_nanos() as u64;
            match decision {
                Some(l) => l,
                None => {
                    // Drained everywhere: derive the verdict from the
                    // same shared snapshot every worker sees.
                    break 'run match (&sh.error, sh.finished.iter().sum::<u32>()) {
                        (Some(e), _) => Err(e.clone()),
                        (None, f) if f == n_cores => Ok(()),
                        (None, f) => {
                            let stuck = eng.stuck_cores().join("\n");
                            Err(format!(
                                "deadlock: all shards drained with {f}/{n_cores} cores \
                                 finished\nshard {me} stuck cores:\n{stuck}"
                            ))
                        }
                    };
                }
            }
        };
        // --- dispatch window outside the lock ---
        rounds += 1;
        if record_trace && exec.len() < EXEC_CAP {
            exec.push(ExecEvent { kind: ExecKind::Window, cycle: limit, shard: me, arg: rounds });
        }
        let b0 = Instant::now();
        let res = eng.run_window(limit).map_err(|e| format!("{e:#}"));
        busy_ns += b0.elapsed().as_nanos() as u64;
        let b1 = Instant::now();
        let mut sh = shared.mu.lock().unwrap();
        if let Err(e) = res {
            sh.error.get_or_insert(e.clone());
            sh.done = true;
            shared.cv.notify_all();
            break 'run Err(e);
        }
        // Push real mail and the clock-row update atomically: a
        // receiver that reads the new promise under this lock has
        // either drained this mail already or will find it in its box.
        let mut sent_real = vec![false; n];
        for dest in 0..threads {
            if dest == me {
                continue;
            }
            let out = eng.take_outbox(dest);
            if !out.is_empty() {
                sent_real[dest as usize] = true;
                sh.mail[dest as usize][me as usize].extend(out);
            }
        }
        publish(&mut sh, &eng, me as usize, n, &sent_real);
        shared.cv.notify_all();
        busy_ns += b1.elapsed().as_nanos() as u64;
    };
    verdict?;
    let out = eng.finalize_shard();
    let load = ShardLoad { shard: me, events: out.stats.events, busy_ns, wait_ns };
    Ok(WorkerDone { out, load, epochs: rounds, exec })
}

/// Advance `ck` past the earliest pending event by one rebalance
/// interval (`rebalance_every` windows of the current minimum
/// lookahead).  Anchoring on the published minimum — a deterministic
/// simulated quantity at the cut — keeps sparse stretches from
/// spinning through empty checkpoints.
fn advance_ck(sh: &mut CmbShared, rebalance_every: u32) {
    let base = sh.next_fire.iter().filter_map(|f| *f).min().unwrap_or(sh.ck);
    let interval = (rebalance_every as Cycle).saturating_mul(sh.la.min);
    sh.ck = base.max(sh.ck).saturating_add(interval);
}

/// The four-phase rebalance rendezvous (DESIGN.md §11.6).  Entered by
/// every worker once the predicate holds; the lock is held throughout
/// (condvar waits release it at the phase edges).  Phase 1 publishes
/// counts and decides; phase 2 extracts lost tiles; phase 3 installs
/// gains, resets channel clocks to `ck + L_new`, and republishes.
#[allow(clippy::too_many_arguments)]
fn rendezvous<'a>(
    mut sh: MutexGuard<'a, CmbShared>,
    cv: &Condvar,
    eng: &mut Engine,
    part: &mut TilePartition,
    me: u32,
    n: usize,
    workload: &Workload,
    cfg: &SystemConfig,
    rebalance_every: u32,
    mut trace_exec: Option<&mut Vec<ExecEvent>>,
) -> MutexGuard<'a, CmbShared> {
    let entry_gen = sh.gen;
    if sh.phase == 0 {
        sh.phase = 1;
        sh.arrived = 0;
        sh.staged = None;
    }
    debug_assert_eq!(sh.phase, 1, "joined a rendezvous past its counts phase");
    // --- phase 1: counts ---
    {
        let (lo, hi) = part.range(me);
        for tile in lo..hi {
            sh.counts[tile as usize] = eng.tile_counts()[tile as usize];
        }
    }
    sh.arrived += 1;
    if sh.arrived as usize == n {
        let new_part = TilePartition::from_counts(&sh.counts, n as u32);
        if new_part == *part {
            // No movement: bump the checkpoint and resume.
            advance_ck(&mut sh, rebalance_every);
            sh.gen += 1;
            sh.phase = 0;
            sh.arrived = 0;
            cv.notify_all();
            return sh;
        }
        sh.staged = Some(new_part);
        sh.rebalances += 1;
        sh.phase = 2;
        sh.arrived = 0;
        cv.notify_all();
    } else {
        while sh.gen == entry_gen && sh.phase == 1 {
            sh = cv.wait(sh).unwrap();
        }
        if sh.gen != entry_gen {
            return sh; // no-movement fast path completed by the last arriver
        }
    }
    // --- phase 2: extract lost tiles ---
    let new_part = sh.staged.clone().expect("partition staged in phase 2");
    let keeps = extract_lost_tiles(eng, part, &new_part, me, workload, |t, m| {
        sh.migrations[t as usize] = Some(m)
    });
    sh.arrived += 1;
    if sh.arrived as usize == n {
        sh.la = lookahead_table(cfg, &new_part);
        sh.phase = 3;
        sh.arrived = 0;
        cv.notify_all();
    } else {
        while sh.phase == 2 {
            sh = cv.wait(sh).unwrap();
        }
    }
    // --- phase 3: install gains, reset clocks, republish ---
    let moved = install_gained_tiles(eng, part, &new_part, me, keeps, |t| {
        sh.migrations[t as usize].take().expect("old owner stashed the tile in phase 2")
    });
    sh.migrated += moved;
    if let Some(exec) = trace_exec.as_deref_mut() {
        if exec.len() < EXEC_CAP {
            exec.push(ExecEvent { kind: ExecKind::Rebalance, cycle: sh.ck, shard: me, arg: moved });
        }
    }
    // Clock reset: every pending event fires at or beyond `ck` and no
    // receiver dispatched past it (limits are clamped to `ck`), so
    // `ck + L_new(me, j)` is a valid promise and stale overshoot
    // promises from the old matrix were never consumed.
    let ck = sh.ck;
    for j in 0..n {
        if j != me as usize {
            let l = sh.la.get(me, j as u32);
            sh.clock[me as usize * n + j] = ck.saturating_add(l);
        }
    }
    sh.next_fire[me as usize] = eng.next_fire();
    sh.finished[me as usize] = eng.finished_cores();
    *part = new_part;
    sh.arrived += 1;
    if sh.arrived as usize == n {
        advance_ck(&mut sh, rebalance_every);
        sh.gen += 1;
        sh.phase = 0;
        sh.arrived = 0;
        sh.staged = None;
        cv.notify_all();
    } else {
        while sh.phase == 3 {
            sh = cv.wait(sh).unwrap();
        }
    }
    sh
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ProtocolKind;

    #[test]
    fn lookahead_reflects_the_shard_boundary_cost() {
        let flat = SystemConfig::small(8, ProtocolKind::Tardis);
        let la2 = lookahead(&flat, 2);
        assert!(la2 >= 2, "cross-shard pairs differ in tile, so latency >= hop + flit");
        assert!(lookahead(&flat, 4) <= la2, "finer shards can only shrink the window");
        // On a NUMA fabric with shards == sockets, every cross-shard
        // route crosses a socket link, so the window widens by the
        // numa factor.
        let mut numa = SystemConfig::small(8, ProtocolKind::Tardis);
        numa.topology.sockets = 2;
        numa.topology.numa_ratio = 4;
        let nla = lookahead(&numa, 2);
        assert!(nla > la2, "socket-link lookahead {nla} should exceed mesh lookahead {la2}");
    }

    /// The per-edge matrix is asymmetric on NUMA fabrics: intra-socket
    /// shard pairs see the tight mesh window, cross-socket pairs the
    /// wide link window.
    #[test]
    fn lookahead_matrix_is_asymmetric_on_numa_fabrics() {
        let mut numa = SystemConfig::small(8, ProtocolKind::Tardis);
        numa.topology.sockets = 2;
        numa.topology.numa_ratio = 4;
        // Four shards of two tiles: shards {0,1} share socket 0,
        // shards {2,3} share socket 1.
        let part = TilePartition::balanced(8, 4);
        let t = lookahead_table(&numa, &part);
        let intra = t.get(0, 1);
        let cross = t.get(0, 2);
        assert!(
            intra < cross,
            "intra-socket window {intra} should be tighter than cross-socket {cross}"
        );
        assert_eq!(t.get(0, 2), t.get(2, 0), "symmetric fabric, symmetric windows");
        assert_eq!(t.min, intra.min(t.get(2, 3)), "global min is the tightest mesh edge");
        // The flat fabric has no socket cliff, only mesh distance.
        let flat = SystemConfig::small(8, ProtocolKind::Tardis);
        let tf = lookahead_table(&flat, &TilePartition::balanced(8, 4));
        assert!(tf.get(0, 1) <= tf.get(0, 3), "flat windows grow only with mesh distance");
    }

    #[test]
    fn auto_mode_picks_nullmsg_only_when_windows_spread() {
        // Flat 256-core mesh, 4 shards: boundary-adjacent shards have
        // tight windows while far pairs are wide — null messages let
        // the far pairs run ahead.
        let flat = SystemConfig::small(256, ProtocolKind::Tardis);
        let t = lookahead_table(&flat, &TilePartition::balanced(256, 4));
        assert_eq!(resolve_mode(PdesMode::Auto, &t), PdesMode::NullMsg);
        // Two NUMA sockets split into two shards: every cross-shard
        // route crosses the same link, the matrix is uniform, and the
        // epoch window already is the per-edge bound.
        let mut numa = SystemConfig::small(8, ProtocolKind::Tardis);
        numa.topology.sockets = 2;
        numa.topology.numa_ratio = 4;
        let tn = lookahead_table(&numa, &TilePartition::balanced(8, 2));
        assert_eq!(resolve_mode(PdesMode::Auto, &tn), PdesMode::Epoch);
        // Explicit modes pass through untouched.
        assert_eq!(resolve_mode(PdesMode::Epoch, &t), PdesMode::Epoch);
        assert_eq!(resolve_mode(PdesMode::NullMsg, &tn), PdesMode::NullMsg);
    }

    /// End-to-end canary (the full matrix lives in
    /// tests/determinism.rs): a 2-shard Tardis run is bit-for-bit the
    /// serial run — stats, access log, and per-core finish times.
    #[test]
    fn two_shards_match_serial_bit_for_bit() {
        let spec = crate::workloads::by_name("fft").unwrap();
        let w = crate::trace::synth_workload(&spec.params, 4, 128);
        let cfg = SystemConfig::small(4, ProtocolKind::Tardis);
        let serial = Engine::build(cfg.clone(), &w, Observers::with_sc_log()).run().unwrap();
        let par = run_parallel(cfg, &w, 2, true, false, PdesMode::Epoch, 0).unwrap();
        assert_eq!(par.stats, serial.stats);
        assert_eq!(par.log.records, serial.log.records);
        assert_eq!(par.core_finish, serial.core_finish);
        assert_eq!(par.stats.parallel.threads, 2);
        assert!(par.stats.parallel.epochs > 0);
        assert!(par.stats.parallel.lookahead >= 1);
        assert_eq!(par.stats.parallel.shards.len(), 2);
        let shard_events: u64 = par.stats.parallel.shards.iter().map(|s| s.events).sum();
        assert_eq!(shard_events, par.stats.events, "per-shard event loads sum to the total");
    }

    /// Null-message canary: same bit-for-bit contract under the
    /// channel-clock driver, with and without rebalancing.
    #[test]
    fn nullmsg_mode_matches_serial_bit_for_bit() {
        let spec = crate::workloads::by_name("fft").unwrap();
        let w = crate::trace::synth_workload(&spec.params, 4, 128);
        let cfg = SystemConfig::small(4, ProtocolKind::Tardis);
        let serial = Engine::build(cfg.clone(), &w, Observers::with_sc_log()).run().unwrap();
        for rebalance in [0u32, 4] {
            let par =
                run_parallel(cfg.clone(), &w, 2, true, false, PdesMode::NullMsg, rebalance).unwrap();
            assert_eq!(par.stats, serial.stats, "rebalance_every={rebalance}");
            assert_eq!(par.log.records, serial.log.records, "rebalance_every={rebalance}");
            assert_eq!(par.core_finish, serial.core_finish, "rebalance_every={rebalance}");
        }
    }
}
