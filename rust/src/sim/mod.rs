//! Discrete-event simulation: calendar event queue and engine.
//!
//! The engine advances the protocol controllers along *one* timed
//! path; [`crate::verif`] drives the same controllers through *every*
//! interleaving at small bounds (bounded exhaustive model checking).

pub mod engine;
pub mod event;
pub(crate) mod pdes;

pub use engine::SimResult;
pub use event::{Event, EventQueue};
