//! Discrete-event simulation: event queue and engine.

pub mod engine;
pub mod event;

pub use engine::{run_workload, Engine, SimResult};
pub use event::{Event, EventQueue};
