//! Discrete-event simulation: event queue and engine.

pub mod engine;
pub mod event;

#[allow(deprecated)]
pub use engine::run_workload;
pub use engine::SimResult;
pub use event::{Event, EventQueue};
