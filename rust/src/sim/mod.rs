//! Discrete-event simulation: calendar event queue and engine.

pub mod engine;
pub mod event;

pub use engine::SimResult;
pub use event::{Event, EventQueue};
