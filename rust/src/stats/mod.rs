//! Simulation statistics: every counter the paper's tables and figures
//! need, plus merge/normalize helpers for the experiment harness.

use crate::types::Cycle;

/// The `tardis-serve-v1` / `BENCH_*.json` stat-column vocabulary: one
/// name per [`SimStats`] counter, in the stable wire order
/// [`SimStats::columns`] emits.  `tools/schema_common.py` keeps the
/// Python mirror (`STAT_COLUMNS`); a unit test below parses that file
/// and asserts the two lists match name-for-name, so the 38-column
/// contract lives in exactly two places that cannot drift.
pub const STAT_COLUMNS: [&str; 38] = [
    "sim_cycles",
    "events",
    "memops",
    "loads",
    "stores",
    "atomics",
    "l1_hits",
    "l1_misses",
    "llc_accesses",
    "dram_accesses",
    "renew_requests",
    "renew_success",
    "misspeculations",
    "rollback_cycles",
    "invalidations_sent",
    "broadcasts",
    "sb_stores",
    "sb_forwards",
    "sb_full_stalls",
    "spin_cycles",
    "locks_acquired",
    "barriers_passed",
    "request_flits",
    "data_flits",
    "control_flits",
    "renew_flits",
    "invalidation_flits",
    "dram_flits",
    "total_flits",
    "intra_socket_msgs",
    "inter_socket_msgs",
    "link_crossings",
    "inter_socket_flits",
    "pts_increase_total",
    "pts_increase_self_inc",
    "leases_granted",
    "lease_total",
    "livelock_escalations",
];

/// Network-traffic breakdown by message class, in flits.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrafficStats {
    /// Demand requests (SH_REQ/EX_REQ/GETS/GETX) excluding renewals.
    pub request_flits: u64,
    /// Data-carrying responses (SH_REP/EX_REP/WB_REP/FLUSH_REP...).
    pub data_flits: u64,
    /// Control responses (UPGRADE_REP/RENEW_REP/acks/grants).
    pub control_flits: u64,
    /// Renewal requests (Tardis SH_REQ with matching wts — lease
    /// extension attempts).
    pub renew_flits: u64,
    /// Invalidations + eviction notifications (directory protocols).
    pub invalidation_flits: u64,
    /// LLC <-> memory-controller traffic.
    pub dram_flits: u64,
}

impl TrafficStats {
    pub fn total(&self) -> u64 {
        self.request_flits
            + self.data_flits
            + self.control_flits
            + self.renew_flits
            + self.invalidation_flits
            + self.dram_flits
    }

    pub fn add(&mut self, other: &TrafficStats) {
        self.request_flits += other.request_flits;
        self.data_flits += other.data_flits;
        self.control_flits += other.control_flits;
        self.renew_flits += other.renew_flits;
        self.invalidation_flits += other.invalidation_flits;
        self.dram_flits += other.dram_flits;
    }
}

/// Socket-level traffic split (ccNUMA topologies): every message that
/// enters the network is either intra-socket (source and destination
/// tiles on one socket) or inter-socket (crossed a socket link).  On a
/// flat topology everything is intra-socket.  The `numa` sweep's
/// headline metric: Tardis's owner-free renewals keep `inter_msgs`
/// growing slower than directory invalidation multicasts as the
/// numa-ratio rises (paper §VII).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SocketStats {
    /// Messages delivered without leaving their socket.
    pub intra_msgs: u64,
    /// Messages that crossed at least one inter-socket link.
    pub inter_msgs: u64,
    /// Mesh hops traversed by intra-socket messages.
    pub intra_hops: u64,
    /// Mesh hops traversed by inter-socket messages (their on-chip
    /// segments on both sockets).
    pub inter_hops: u64,
    /// Inter-socket link crossings.
    pub link_crossings: u64,
    /// Flits carried over inter-socket links (the scarce bandwidth).
    pub inter_flits: u64,
}

impl SocketStats {
    pub fn add(&mut self, other: &SocketStats) {
        self.intra_msgs += other.intra_msgs;
        self.inter_msgs += other.inter_msgs;
        self.intra_hops += other.intra_hops;
        self.inter_hops += other.inter_hops;
        self.link_crossings += other.link_crossings;
        self.inter_flits += other.inter_flits;
    }

    /// Messages that entered the network at all.
    pub fn total_msgs(&self) -> u64 {
        self.intra_msgs + self.inter_msgs
    }

    /// Fraction of network messages that crossed a socket link.
    pub fn inter_fraction(&self) -> f64 {
        let total = self.total_msgs();
        if total == 0 {
            0.0
        } else {
            self.inter_msgs as f64 / total as f64
        }
    }
}

/// Tardis timestamp dynamics (paper Table VI).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TimestampStats {
    /// Total pts increase accumulated across all cores.
    pub pts_increase_total: u64,
    /// pts increase attributable to periodic self increment (§III-E).
    pub pts_increase_self_inc: u64,
    /// Number of L1 rebase events (base-delta rollover, §IV-B).
    pub l1_rebases: u64,
    /// Number of LLC rebase events.
    pub l2_rebases: u64,
    /// Cycles spent stalled on rebases.
    pub rebase_stall_cycles: u64,
    /// Shared L1 lines invalidated because delta_rts went negative
    /// during a rebase.
    pub rebase_invalidations: u64,
    /// Shared grants the lease policy served ([`crate::proto::ts`]).
    pub leases_granted: u64,
    /// Sum of granted lease lengths (avg lease = this / leases_granted).
    pub lease_total: u64,
    /// Renewal-starvation escalations: streaks of failed renewals that
    /// crossed the livelock threshold and demoted speculation on that
    /// (core, line) to blocking demands.
    pub livelock_escalations: u64,
}

impl TimestampStats {
    pub fn add(&mut self, other: &TimestampStats) {
        self.pts_increase_total += other.pts_increase_total;
        self.pts_increase_self_inc += other.pts_increase_self_inc;
        self.l1_rebases += other.l1_rebases;
        self.l2_rebases += other.l2_rebases;
        self.rebase_stall_cycles += other.rebase_stall_cycles;
        self.rebase_invalidations += other.rebase_invalidations;
        self.leases_granted += other.leases_granted;
        self.lease_total += other.lease_total;
        self.livelock_escalations += other.livelock_escalations;
    }
}

/// Per-shard load accounting for a parallel (PDES) run.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardLoad {
    pub shard: u32,
    /// Events this shard dispatched (deterministic).
    pub events: u64,
    /// Host time spent simulating + exchanging events.
    pub busy_ns: u64,
    /// Host time spent blocked at epoch barriers.
    pub wait_ns: u64,
}

/// How a parallel run executed: thread/shard count, the conservative
/// lookahead window, epoch count, and per-shard busy/wait timings.
///
/// Host timings are inherently nondeterministic, so `PartialEq` is an
/// unconditional match: two runs of the same `SimSpec` compare equal
/// on `SimStats` regardless of how the work was scheduled — which is
/// exactly the bit-for-bit determinism contract `tests/determinism.rs`
/// asserts between 1-thread and N-thread runs.
#[derive(Debug, Clone, Default)]
pub struct ParallelStats {
    /// Worker threads (= shards); 1 for the serial engine.
    pub threads: u32,
    /// Conservative lookahead (epoch window width) in cycles.
    pub lookahead: Cycle,
    /// Epoch barriers crossed (Epoch mode) or the maximum per-shard
    /// dispatch-round count (NullMsg mode).
    pub epochs: u64,
    /// Host wall-clock of the parallel section, nanoseconds.
    pub wall_ns: u64,
    /// Null messages exchanged (per-edge bound publications with no
    /// real mail attached); 0 in Epoch mode.
    pub null_msgs: u64,
    /// Deterministic repartitions the load balancer performed.
    pub rebalances: u64,
    /// Pending calendar events migrated across shards by rebalances.
    pub migrated_events: u64,
    pub shards: Vec<ShardLoad>,
}

impl PartialEq for ParallelStats {
    fn eq(&self, _other: &Self) -> bool {
        true
    }
}
impl Eq for ParallelStats {}

impl ParallelStats {
    /// Parallel efficiency in (0, threads]: total shard busy time over
    /// wall time.  `threads x efficiency` is the effective speedup
    /// against an ideal serial run of the same work.
    pub fn efficiency(&self) -> f64 {
        if self.wall_ns == 0 || self.shards.is_empty() {
            return 0.0;
        }
        let busy: u64 = self.shards.iter().map(|s| s.busy_ns).sum();
        busy as f64 / self.wall_ns as f64
    }

    /// Per-shard load imbalance: max over mean shard busy time, in
    /// [1, threads].  1.0 = perfectly balanced; the load balancer's
    /// win shows up here as the skewed-workload ratio dropping toward
    /// 1.  Returns 1.0 when there is nothing to compare (empty or
    /// all-idle shards) so the bench schema's >= 1 bound always holds.
    pub fn imbalance(&self) -> f64 {
        let max = self.shards.iter().map(|s| s.busy_ns).max().unwrap_or(0);
        if max == 0 {
            return 1.0;
        }
        let total: u64 = self.shards.iter().map(|s| s.busy_ns).sum();
        let mean = total as f64 / self.shards.len() as f64;
        max as f64 / mean
    }
}

/// Everything measured by one simulation run.
///
/// `PartialEq` is derived so determinism regression tests can require
/// bit-identical runs (every field is an exact integer counter).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Cores in the simulated system (for per-core normalizations).
    pub n_cores: u32,
    /// Benchmark completion time (cycle when the last core finished).
    pub cycles: Cycle,
    /// Discrete events the engine dispatched (queue pops).  The
    /// denominator of the host-side events/sec throughput metric the
    /// bench pipeline tracks (`BENCH_*.json`); deterministic for a
    /// given config + workload.
    pub events: u64,
    /// Completed memory operations (loads + stores + atomics),
    /// including spin re-loads.
    pub memops: u64,
    /// Loads (incl. spin polls), stores, atomics.
    pub loads: u64,
    pub stores: u64,
    pub atomics: u64,

    /// L1 data-cache hits/misses (demand, excluding renew checks).
    pub l1_hits: u64,
    pub l1_misses: u64,

    /// Requests processed by the LLC / timestamp managers, including
    /// renewals (paper Fig. 5 normalizes renewals by this).
    pub llc_accesses: u64,
    /// DRAM line fetches + writebacks.
    pub dram_accesses: u64,

    /// Tardis renewals: lease-extension requests and their outcomes.
    pub renew_requests: u64,
    pub renew_success: u64,
    /// Failed renewals that had been speculated through (rollback).
    pub misspeculations: u64,
    /// Cycles charged to rollback penalties.
    pub rollback_cycles: u64,

    /// Directory invalidations sent (MSI/Ackwise), and broadcasts.
    pub invalidations_sent: u64,
    pub broadcasts: u64,

    /// TSO store buffer: stores retired into a core's buffer, loads
    /// served by forwarding from the core's own *pending* stores
    /// (store buffer, or — on the OoO core — an older in-ROB store,
    /// the store-queue forwarding real TSO machines do; counting both
    /// keeps the metric comparable with the in-order core, where
    /// every pending store lives in the buffer), and issue stalls on
    /// a full buffer.  All zero under `Consistency::Sc`.  Like
    /// `loads`, `sb_forwards` counts events: a forwarded load inside
    /// a squashed speculation window is re-executed and re-counted.
    pub sb_stores: u64,
    pub sb_forwards: u64,
    pub sb_full_stalls: u64,

    /// Cycles cores spent spinning (lock/barrier waits).
    pub spin_cycles: u64,
    /// Lock acquisitions and barrier episodes completed.
    pub locks_acquired: u64,
    pub barriers_passed: u64,

    pub traffic: TrafficStats,
    /// Intra- vs inter-socket traffic split (all intra when flat).
    pub socket: SocketStats,
    pub ts: TimestampStats,
    /// Parallel-execution accounting (empty for serial runs).  Not a
    /// simulated quantity: compares always-equal and is excluded from
    /// [`SimStats::columns`], so determinism checks and the wire
    /// schema see identical stats however the run was scheduled.
    pub parallel: ParallelStats,
}

impl SimStats {
    /// Instructions(memops)-per-cycle style throughput metric.  The
    /// paper reports throughput normalized to baseline MSI; the ratio
    /// of `throughput()` across runs of the same workload gives that.
    pub fn throughput(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.memops as f64 / self.cycles as f64
        }
    }

    /// Renew requests as a fraction of LLC accesses (Fig. 5).
    pub fn renew_rate(&self) -> f64 {
        if self.llc_accesses == 0 {
            0.0
        } else {
            self.renew_requests as f64 / self.llc_accesses as f64
        }
    }

    /// Misspeculations as a fraction of LLC accesses (Fig. 5).
    pub fn misspeculation_rate(&self) -> f64 {
        if self.llc_accesses == 0 {
            0.0
        } else {
            self.misspeculations as f64 / self.llc_accesses as f64
        }
    }

    /// Cycles per unit of per-core pts increase (paper Table VI
    /// "Ts. Incr. Rate"): each core's pts advances once every this
    /// many cycles on average.
    pub fn ts_incr_rate(&self) -> f64 {
        if self.ts.pts_increase_total == 0 {
            f64::INFINITY
        } else {
            let per_core = self.ts.pts_increase_total as f64 / self.n_cores.max(1) as f64;
            self.cycles as f64 / per_core
        }
    }

    /// Fraction of pts increase caused by self increment (Table VI).
    pub fn self_inc_fraction(&self) -> f64 {
        if self.ts.pts_increase_total == 0 {
            0.0
        } else {
            self.ts.pts_increase_self_inc as f64 / self.ts.pts_increase_total as f64
        }
    }

    /// Average lease length the timestamp managers granted (the
    /// lease-policy sweep's headline metric alongside renew_rate).
    pub fn avg_lease(&self) -> f64 {
        if self.ts.leases_granted == 0 {
            0.0
        } else {
            self.ts.lease_total as f64 / self.ts.leases_granted as f64
        }
    }

    /// Every integer counter as a flat `(name, value)` list — the
    /// column set of the `tardis-serve-v1` payload (DESIGN.md §10).
    /// Names mirror the `BENCH_*.json` fields where both schemas
    /// carry the stat (`sim_cycles`, `memops`, `events`,
    /// `intra_socket_msgs`, `inter_socket_msgs`), so the `tools/`
    /// validators share one vocabulary.  Names and order come from
    /// [`STAT_COLUMNS`] — stable, part of the wire schema, and
    /// asserted against `tools/schema_common.py`'s mirror by test.
    pub fn columns(&self) -> Vec<(&'static str, u64)> {
        let values: [u64; 38] = [
            self.cycles,
            self.events,
            self.memops,
            self.loads,
            self.stores,
            self.atomics,
            self.l1_hits,
            self.l1_misses,
            self.llc_accesses,
            self.dram_accesses,
            self.renew_requests,
            self.renew_success,
            self.misspeculations,
            self.rollback_cycles,
            self.invalidations_sent,
            self.broadcasts,
            self.sb_stores,
            self.sb_forwards,
            self.sb_full_stalls,
            self.spin_cycles,
            self.locks_acquired,
            self.barriers_passed,
            self.traffic.request_flits,
            self.traffic.data_flits,
            self.traffic.control_flits,
            self.traffic.renew_flits,
            self.traffic.invalidation_flits,
            self.traffic.dram_flits,
            self.traffic.total(),
            self.socket.intra_msgs,
            self.socket.inter_msgs,
            self.socket.link_crossings,
            self.socket.inter_flits,
            self.ts.pts_increase_total,
            self.ts.pts_increase_self_inc,
            self.ts.leases_granted,
            self.ts.lease_total,
            self.ts.livelock_escalations,
        ];
        STAT_COLUMNS.iter().zip(values).map(|(&name, value)| (name, value)).collect()
    }

    /// Merge another run's counters into this one — the PDES shard
    /// reduction.  Every field is a commutative sum except `n_cores`
    /// (a system property, kept) and `cycles` (the max over per-core
    /// finish times, computed by the caller once all shards are in);
    /// `parallel` is filled by the driver afterwards.
    pub fn absorb(&mut self, other: &SimStats) {
        self.events += other.events;
        self.memops += other.memops;
        self.loads += other.loads;
        self.stores += other.stores;
        self.atomics += other.atomics;
        self.l1_hits += other.l1_hits;
        self.l1_misses += other.l1_misses;
        self.llc_accesses += other.llc_accesses;
        self.dram_accesses += other.dram_accesses;
        self.renew_requests += other.renew_requests;
        self.renew_success += other.renew_success;
        self.misspeculations += other.misspeculations;
        self.rollback_cycles += other.rollback_cycles;
        self.invalidations_sent += other.invalidations_sent;
        self.broadcasts += other.broadcasts;
        self.sb_stores += other.sb_stores;
        self.sb_forwards += other.sb_forwards;
        self.sb_full_stalls += other.sb_full_stalls;
        self.spin_cycles += other.spin_cycles;
        self.locks_acquired += other.locks_acquired;
        self.barriers_passed += other.barriers_passed;
        self.traffic.add(&other.traffic);
        self.socket.add(&other.socket);
        self.ts.add(&other.ts);
    }

    /// L1 miss rate over demand accesses.
    pub fn l1_miss_rate(&self) -> f64 {
        let total = self.l1_hits + self.l1_misses;
        if total == 0 {
            0.0
        } else {
            self.l1_misses as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_total_sums_all_classes() {
        let t = TrafficStats {
            request_flits: 1,
            data_flits: 2,
            control_flits: 3,
            renew_flits: 4,
            invalidation_flits: 5,
            dram_flits: 6,
        };
        assert_eq!(t.total(), 21);
    }

    #[test]
    fn traffic_add_accumulates() {
        let mut a = TrafficStats::default();
        let b = TrafficStats { request_flits: 2, data_flits: 7, ..Default::default() };
        a.add(&b);
        a.add(&b);
        assert_eq!(a.request_flits, 4);
        assert_eq!(a.data_flits, 14);
    }

    #[test]
    fn derived_rates() {
        let s = SimStats {
            n_cores: 1,
            cycles: 1000,
            memops: 500,
            llc_accesses: 100,
            renew_requests: 25,
            misspeculations: 1,
            ts: TimestampStats {
                pts_increase_total: 10,
                pts_increase_self_inc: 4,
                ..Default::default()
            },
            ..Default::default()
        };
        assert!((s.throughput() - 0.5).abs() < 1e-12);
        assert!((s.renew_rate() - 0.25).abs() < 1e-12);
        assert!((s.misspeculation_rate() - 0.01).abs() < 1e-12);
        assert!((s.ts_incr_rate() - 100.0).abs() < 1e-12);
        assert!((s.self_inc_fraction() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn zero_division_guards() {
        let s = SimStats::default();
        assert_eq!(s.throughput(), 0.0);
        assert_eq!(s.renew_rate(), 0.0);
        assert_eq!(s.l1_miss_rate(), 0.0);
        assert!(s.ts_incr_rate().is_infinite());
        assert_eq!(s.socket.inter_fraction(), 0.0);
    }

    #[test]
    fn columns_expose_every_counter_with_unique_names() {
        let s = SimStats {
            cycles: 7,
            events: 9,
            memops: 5,
            traffic: TrafficStats { renew_flits: 3, ..Default::default() },
            socket: SocketStats { inter_msgs: 2, ..Default::default() },
            ..Default::default()
        };
        let cols = s.columns();
        let get = |name: &str| {
            cols.iter().find(|(n, _)| *n == name).map(|(_, v)| *v).unwrap_or_else(|| {
                panic!("missing column {name}")
            })
        };
        assert_eq!(get("sim_cycles"), 7);
        assert_eq!(get("events"), 9);
        assert_eq!(get("memops"), 5);
        assert_eq!(get("renew_flits"), 3);
        assert_eq!(get("total_flits"), 3);
        assert_eq!(get("inter_socket_msgs"), 2);
        let mut names: Vec<&str> = cols.iter().map(|(n, _)| *n).collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate column names");
        assert_eq!(before, 38, "column count is part of the wire schema");
    }

    /// The 38-column wire contract has exactly two homes: the
    /// [`STAT_COLUMNS`] const here and `STAT_COLUMNS` in
    /// `tools/schema_common.py`.  Parse the Python mirror and require
    /// a name-for-name, order-for-order match.
    #[test]
    fn stat_columns_match_the_python_mirror() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../tools/schema_common.py");
        let src = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path}: {e}"));
        let body = src
            .split("STAT_COLUMNS = (")
            .nth(1)
            .expect("tools/schema_common.py must define STAT_COLUMNS")
            .split(')')
            .next()
            .unwrap();
        let python: Vec<&str> = body
            .lines()
            .filter_map(|l| l.trim().strip_prefix('"')?.strip_suffix("\","))
            .collect();
        assert_eq!(
            python, STAT_COLUMNS,
            "rust STAT_COLUMNS and tools/schema_common.py STAT_COLUMNS drifted"
        );
    }

    #[test]
    fn absorb_sums_counters_and_parallel_stats_never_break_equality() {
        let mut a = SimStats { n_cores: 4, events: 10, memops: 5, ..Default::default() };
        let b = SimStats {
            n_cores: 4,
            events: 3,
            memops: 2,
            traffic: TrafficStats { data_flits: 5, ..Default::default() },
            socket: SocketStats { inter_msgs: 1, ..Default::default() },
            ts: TimestampStats { leases_granted: 2, ..Default::default() },
            ..Default::default()
        };
        a.absorb(&b);
        assert_eq!(a.events, 13);
        assert_eq!(a.memops, 7);
        assert_eq!(a.n_cores, 4, "n_cores is a system property, not a sum");
        assert_eq!(a.traffic.data_flits, 5);
        assert_eq!(a.socket.inter_msgs, 1);
        assert_eq!(a.ts.leases_granted, 2);
        // Host-time accounting never breaks run equality: the PDES
        // determinism contract compares SimStats across schedules.
        let mut c = a.clone();
        c.parallel = ParallelStats {
            threads: 4,
            lookahead: 9,
            epochs: 3,
            wall_ns: 200,
            null_msgs: 7,
            rebalances: 1,
            migrated_events: 42,
            shards: vec![ShardLoad { shard: 0, events: 13, busy_ns: 150, wait_ns: 10 }],
        };
        assert_eq!(a, c);
        assert!((c.parallel.efficiency() - 0.75).abs() < 1e-12);
        assert_eq!(ParallelStats::default().efficiency(), 0.0);
    }

    #[test]
    fn imbalance_is_max_over_mean_and_floors_at_one() {
        let load = |busy_ns| ShardLoad { shard: 0, events: 0, busy_ns, wait_ns: 0 };
        let p = ParallelStats { shards: vec![load(300), load(100)], ..Default::default() };
        assert!((p.imbalance() - 1.5).abs() < 1e-12);
        let even = ParallelStats { shards: vec![load(5), load(5)], ..Default::default() };
        assert!((even.imbalance() - 1.0).abs() < 1e-12);
        assert_eq!(ParallelStats::default().imbalance(), 1.0, "no shards: neutral");
        let idle = ParallelStats { shards: vec![load(0), load(0)], ..Default::default() };
        assert_eq!(idle.imbalance(), 1.0, "all-idle shards: neutral");
    }

    #[test]
    fn socket_split_fractions() {
        let s = SocketStats { intra_msgs: 6, inter_msgs: 2, ..Default::default() };
        assert_eq!(s.total_msgs(), 8);
        assert!((s.inter_fraction() - 0.25).abs() < 1e-12);
    }
}
