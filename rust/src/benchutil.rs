//! Bench harness for the `harness = false` bench targets (criterion is
//! not in this image's crate registry).  Measures wall-clock per
//! iteration with warmup, prints criterion-style lines, and appends
//! machine-readable rows to target/bench_results.csv.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<42} {:>12.3?}/iter  (min {:.3?}, max {:.3?}, n={})",
            self.name, self.mean, self.min, self.max, self.iters
        );
    }
}

/// Time `f` for `iters` iterations after one warmup run.
pub fn bench<T>(name: &str, iters: u32, mut f: impl FnMut() -> T) -> BenchResult {
    let _warm = f();
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        let out = f();
        times.push(t0.elapsed());
        std::hint::black_box(out);
    }
    let total: Duration = times.iter().sum();
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters,
        min: times.iter().min().copied().unwrap_or_default(),
        max: times.iter().max().copied().unwrap_or_default(),
    };
    res.report();
    append_csv(&res);
    res
}

fn append_csv(r: &BenchResult) {
    use std::io::Write;
    let path = std::path::Path::new("target").join("bench_results.csv");
    let new = !path.exists();
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        if new {
            let _ = writeln!(f, "name,iters,mean_ns,min_ns,max_ns");
        }
        let _ = writeln!(
            f,
            "{},{},{},{},{}",
            r.name,
            r.iters,
            r.mean.as_nanos(),
            r.min.as_nanos(),
            r.max.as_nanos()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-sum", 3, || (0..1000u64).sum::<u64>());
        assert_eq!(r.iters, 3);
        assert!(r.min <= r.mean && r.mean <= r.max + Duration::from_nanos(1));
    }
}
