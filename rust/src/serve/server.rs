//! The `tardis serve` TCP server: long-lived, multi-threaded,
//! line-delimited JSON.
//!
//! Threading layout (DESIGN.md §10):
//!
//! * one **accept thread** polls a nonblocking listener (~50 ms) so it
//!   can notice the shutdown flag between connections;
//! * one **connection thread** per client reads frames with a short
//!   read timeout (again so shutdown is noticed promptly) and answers
//!   control frames inline;
//! * one **writer thread** per client owns a cloned stream and drains
//!   an mpsc channel of outgoing lines — batch jobs on pool threads
//!   and the connection thread interleave responses without sharing
//!   the socket;
//! * sweeps fan out over one shared [`WorkerPool`]: every point is an
//!   independent `SimSpec -> SimBuilder -> run` session on a pool
//!   thread, so batches from concurrent clients interleave at
//!   point granularity.
//!
//! Shutdown is graceful end-to-end: the flag stops the accept loop,
//! each connection thread joins its in-flight batch threads (which
//! wait for their pool jobs), result frames drain through the writer,
//! and finally the pool itself drains and joins.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::api::{Observer, SimSpec};
use crate::coordinator::WorkerPool;
use crate::obs::{IntervalMetrics, MetricsWindow};
use crate::prog::checker::LogRecord;
use crate::stats::SimStats;
use crate::types::Cycle;

use super::columns::{self, BatchTiming, PointResult, SCHEMA};
use super::json::escape;
use super::request::{self, Request, SweepRequest};

/// Largest accepted request frame (a 1024-point sweep with every knob
/// spelled out fits in well under 1 MB).
const MAX_FRAME_BYTES: usize = 8 * 1024 * 1024;

/// How long blocking calls sleep before re-checking the shutdown flag.
const POLL: Duration = Duration::from_millis(50);

/// Server configuration (the `tardis serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7436`; port 0 picks a free port
    /// (the test harness's ephemeral-port mode).
    pub addr: String,
    /// Simulation worker threads (0 = available parallelism).
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { addr: "127.0.0.1:7436".into(), workers: 0 }
    }
}

/// A running server.  Dropping the handle shuts the server down.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    pool: Arc<WorkerPool>,
}

impl Server {
    /// Bind and start serving in background threads; returns once the
    /// listener is live (so the bound address is known).
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let pool = Arc::new(WorkerPool::new(cfg.workers));
        let accept_thread = {
            let shutdown = Arc::clone(&shutdown);
            let pool = Arc::clone(&pool);
            std::thread::spawn(move || accept_loop(listener, shutdown, pool))
        };
        Ok(Self { addr, shutdown, accept_thread: Some(accept_thread), pool })
    }

    /// The actual bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// True once a client frame or [`Server::shutdown`] requested
    /// shutdown.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Block until shutdown is requested, then drain and join
    /// everything.  The `tardis serve` main loop.
    pub fn join(mut self) {
        while !self.shutdown_requested() {
            std::thread::sleep(POLL);
        }
        self.drain();
    }

    /// Request shutdown, drain in-flight sessions, join all threads.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.drain();
    }

    fn drain(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        // Connection threads have joined their batch threads by now;
        // the pool drains whatever is still queued.
        self.pool.shutdown();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.drain();
    }
}

fn accept_loop(listener: TcpListener, shutdown: Arc<AtomicBool>, pool: Arc<WorkerPool>) {
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let shutdown = Arc::clone(&shutdown);
                let pool = Arc::clone(&pool);
                conns.push(std::thread::spawn(move || {
                    // A broken socket tears down one connection, not
                    // the server.
                    let _ = serve_connection(stream, shutdown, pool);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(_) => std::thread::sleep(POLL),
        }
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
}

fn serve_connection(
    stream: TcpStream,
    shutdown: Arc<AtomicBool>,
    pool: Arc<WorkerPool>,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let (tx, rx) = mpsc::channel::<String>();
    let writer_stream = stream.try_clone()?;
    let writer = std::thread::spawn(move || write_loop(writer_stream, rx));
    let mut batches: Vec<std::thread::JoinHandle<()>> = Vec::new();

    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => break, // client hung up
            Ok(_) if buf.last() != Some(&b'\n') => continue, // partial line, keep reading
            Ok(_) => {
                let line = String::from_utf8_lossy(&buf).into_owned();
                buf.clear();
                if line.trim().is_empty() {
                    continue;
                }
                if !handle_frame(&line, &tx, &shutdown, &pool, &mut batches) {
                    break;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Read timeout: partial bytes (if any) stay in `buf`;
                // loop back to re-check the shutdown flag.
                if buf.len() > MAX_FRAME_BYTES {
                    let _ = tx.send(error_frame(None, "frame too large"));
                    break;
                }
                continue;
            }
            Err(_) => break,
        }
        if buf.len() > MAX_FRAME_BYTES {
            let _ = tx.send(error_frame(None, "frame too large"));
            break;
        }
    }

    // Drain this connection's in-flight batches: their result frames
    // flow through the writer before the socket closes.
    for b in batches {
        let _ = b.join();
    }
    if shutdown.load(Ordering::SeqCst) {
        let _ = tx.send("{\"type\": \"bye\"}".to_string());
    }
    drop(tx);
    let _ = writer.join();
    Ok(())
}

/// Handle one decoded line; returns false when the connection should
/// close.
fn handle_frame(
    line: &str,
    tx: &mpsc::Sender<String>,
    shutdown: &Arc<AtomicBool>,
    pool: &Arc<WorkerPool>,
    batches: &mut Vec<std::thread::JoinHandle<()>>,
) -> bool {
    match request::decode(line) {
        Ok(Request::Hello) => {
            let _ = tx.send(hello_frame(pool.workers()));
            true
        }
        Ok(Request::Ping) => {
            let _ = tx.send("{\"type\": \"pong\"}".to_string());
            true
        }
        Ok(Request::Shutdown) => {
            shutdown.store(true, Ordering::SeqCst);
            false
        }
        Ok(Request::Sweep(req)) => {
            let depth = pool.queue_depth();
            let _ = tx.send(ack_frame(&req.id, req.points.len(), depth));
            let pool = Arc::clone(pool);
            let tx = tx.clone();
            batches.push(std::thread::spawn(move || {
                let t0 = Instant::now();
                let frame = match run_batch(&pool, &req, Some(&tx)) {
                    Ok(results) => {
                        let timing =
                            BatchTiming { wall: t0.elapsed(), queue_depth_at_submit: depth };
                        result_frame(&req, pool.workers(), &timing, &results)
                    }
                    Err(e) => error_frame(Some(&req.id), &format!("{e:#}")),
                };
                let _ = tx.send(frame);
            }));
            true
        }
        Err(e) => {
            let _ = tx.send(error_frame(None, &format!("{e:#}")));
            true
        }
    }
}

fn write_loop(mut stream: TcpStream, rx: mpsc::Receiver<String>) {
    while let Ok(mut line) = rx.recv() {
        line.push('\n');
        if stream.write_all(line.as_bytes()).is_err() {
            break;
        }
        let _ = stream.flush();
    }
}

/// Run every point of a sweep on the pool and collect results in
/// point order.  Blocks until the whole batch is done; progress and
/// `point_done` frames stream through `events` as points run.  This
/// is the serve execution core, also driven directly (no socket) by
/// the determinism tests.
pub fn run_batch(
    pool: &WorkerPool,
    req: &SweepRequest,
    events: Option<&mpsc::Sender<String>>,
) -> Result<Vec<PointResult>> {
    let n = req.points.len();
    let (done_tx, done_rx) = mpsc::channel::<(usize, Result<PointResult>)>();
    for (i, spec) in req.points.iter().enumerate() {
        let spec = spec.clone();
        let done = done_tx.clone();
        let events = events.cloned();
        let id = req.id.clone();
        let progress_every = req.progress_every;
        pool.submit(move || {
            let res = run_point(&id, i, &spec, progress_every, events.as_ref());
            let _ = done.send((i, res));
        })?;
    }
    drop(done_tx);

    let mut out: Vec<Option<PointResult>> = (0..n).map(|_| None).collect();
    let mut errors: Vec<String> = Vec::new();
    for _ in 0..n {
        let (i, res) = done_rx.recv().context("worker pool shut down mid-batch")?;
        match res {
            Ok(p) => out[i] = Some(p),
            Err(e) => errors.push(format!("point {i}: {e}")),
        }
    }
    if !errors.is_empty() {
        errors.sort(); // deterministic error text regardless of finish order
        anyhow::bail!("{} point(s) failed: {}", errors.len(), errors.join("; "));
    }
    Ok(out.into_iter().map(|p| p.unwrap()).collect())
}

/// One independent simulation session on a pool thread.
fn run_point(
    batch_id: &str,
    index: usize,
    spec: &SimSpec,
    progress_every: u64,
    events: Option<&mpsc::Sender<String>>,
) -> Result<PointResult> {
    let mut b = spec.builder()?;
    if progress_every > 0 {
        if let Some(tx) = events {
            b = b.observe(ServeProgressObserver::new(
                batch_id.to_string(),
                index,
                progress_every,
                tx.clone(),
            ));
            // Drive the observer's interval-metrics window off the
            // engine's cycle sampler (same granularity; purely
            // observational, so the stats stay bit-identical).
            b = b.sample_every(progress_every);
        }
    }
    let report = b.run()?;
    if let Some(tx) = events {
        let _ = tx.send(point_done_frame(batch_id, index, report.elapsed));
    }
    Ok(PointResult { spec: spec.clone(), stats: report.stats, elapsed: report.elapsed })
}

/// Streams per-point progress frames through the connection's writer
/// channel: one frame every `every` committed memory operations.
/// Purely observational — attaching it cannot change the simulated
/// statistics, so progress-streaming runs stay bit-identical to bare
/// ones (asserted in `tests/serve.rs`).
pub struct ServeProgressObserver {
    batch_id: String,
    point: usize,
    every: u64,
    committed: u64,
    window: MetricsWindow,
    last: IntervalMetrics,
    tx: mpsc::Sender<String>,
}

impl ServeProgressObserver {
    pub fn new(batch_id: String, point: usize, every: u64, tx: mpsc::Sender<String>) -> Self {
        Self {
            batch_id,
            point,
            every: every.max(1),
            committed: 0,
            window: MetricsWindow::default(),
            last: IntervalMetrics::default(),
            tx,
        }
    }
}

impl Observer for ServeProgressObserver {
    fn on_commit(&mut self, _rec: &LogRecord) {
        self.committed += 1;
        if self.committed % self.every == 0 {
            let _ =
                self.tx.send(progress_frame(&self.batch_id, self.point, self.committed, self.last));
        }
    }

    fn on_sample(&mut self, _now: Cycle, stats: &SimStats) {
        self.last = self.window.tick(stats);
    }
}

// ---- response frames (hand-rolled JSON; one line each) -------------

pub fn hello_frame(workers: usize) -> String {
    format!(
        "{{\"type\": \"hello\", \"server\": \"tardis-serve\", \"schema\": {}, \"workers\": {workers}}}",
        escape(SCHEMA)
    )
}

pub fn ack_frame(batch_id: &str, n_points: usize, queue_depth: usize) -> String {
    format!(
        "{{\"type\": \"ack\", \"batch_id\": {}, \"n_points\": {n_points}, \"queue_depth\": {queue_depth}}}",
        escape(batch_id)
    )
}

pub fn progress_frame(batch_id: &str, point: usize, memops: u64, m: IntervalMetrics) -> String {
    format!(
        "{{\"type\": \"progress\", \"batch_id\": {}, \"point\": {point}, \"memops\": {memops}, \
         \"renew_rate\": {:.6}, \"avg_lease\": {:.6}}}",
        escape(batch_id),
        m.renew_rate,
        m.avg_lease
    )
}

pub fn point_done_frame(batch_id: &str, point: usize, elapsed: Duration) -> String {
    format!(
        "{{\"type\": \"point_done\", \"batch_id\": {}, \"point\": {point}, \"wall_s\": {:.6}}}",
        escape(batch_id),
        elapsed.as_secs_f64()
    )
}

pub fn result_frame(
    req: &SweepRequest,
    workers: usize,
    timing: &BatchTiming,
    results: &[PointResult],
) -> String {
    format!(
        "{{\"type\": \"result\", \"batch_id\": {}, \"payload\": {}}}",
        escape(&req.id),
        columns::payload(&req.id, req.seed, workers, timing, results)
    )
}

pub fn error_frame(batch_id: Option<&str>, message: &str) -> String {
    match batch_id {
        Some(id) => format!(
            "{{\"type\": \"error\", \"batch_id\": {}, \"message\": {}}}",
            escape(id),
            escape(message)
        ),
        None => format!("{{\"type\": \"error\", \"message\": {}}}", escape(message)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::json;

    #[test]
    fn frames_are_single_line_valid_json() {
        let timing = BatchTiming { wall: Duration::ZERO, queue_depth_at_submit: 0 };
        let req = SweepRequest {
            id: "b\"1".into(),
            seed: None,
            progress_every: 0,
            points: vec![SimSpec::new("fft")],
        };
        for frame in [
            hello_frame(4),
            ack_frame("b\"1", 2, 1),
            progress_frame("b", 0, 1000, IntervalMetrics::default()),
            point_done_frame("b", 1, Duration::from_millis(3)),
            result_frame(&req, 4, &timing, &[]),
            error_frame(None, "bad \"JSON\""),
            error_frame(Some("b"), "x\ny"),
        ] {
            assert!(!frame.contains('\n'), "frame must be one line: {frame}");
            let v = json::parse(&frame).unwrap_or_else(|e| panic!("{frame}: {e}"));
            assert!(v.get("type").is_some());
        }
    }

    #[test]
    fn run_batch_runs_points_in_order_on_the_pool() {
        let pool = WorkerPool::new(4);
        let mk = |workload: &str| {
            let mut s = SimSpec::new(workload);
            s.cores = 2;
            s.trace_len = Some(64);
            s
        };
        let req = SweepRequest {
            id: "t".into(),
            seed: None,
            progress_every: 0,
            points: vec![mk("fft"), mk("barnes"), mk("fft"), mk("lu-c")],
        };
        let results = run_batch(&pool, &req, None).unwrap();
        assert_eq!(results.len(), 4);
        assert_eq!(results[0].spec.workload, "fft");
        assert_eq!(results[1].spec.workload, "barnes");
        assert_eq!(results[3].spec.workload, "lu-c");
        // Identical specs at different batch slots give identical bits.
        assert_eq!(results[0].stats, results[2].stats);
        assert!(results[0].stats.cycles > 0);
    }

    #[test]
    fn run_batch_streams_progress_and_point_done() {
        let pool = WorkerPool::new(2);
        let mut spec = SimSpec::new("fft");
        spec.cores = 2;
        spec.trace_len = Some(64);
        let req = SweepRequest {
            id: "p".into(),
            seed: None,
            progress_every: 10,
            points: vec![spec],
        };
        let (tx, rx) = mpsc::channel();
        let results = run_batch(&pool, &req, Some(&tx)).unwrap();
        drop(tx);
        let events: Vec<String> = rx.iter().collect();
        assert!(!events.is_empty());
        let last = json::parse(events.last().unwrap()).unwrap();
        assert_eq!(last.get("type").unwrap().as_str(), Some("point_done"));
        assert!(
            events.iter().any(|e| e.contains("\"progress\"")),
            "expected progress frames, got {events:?}"
        );
        // Streaming progress must not perturb the simulation.
        let bare = run_batch(&pool, &SweepRequest { progress_every: 0, ..req }, None).unwrap();
        assert_eq!(results[0].stats, bare[0].stats);
    }
}
