//! Wire-frame -> request decoding for the serve protocol.
//!
//! Every client frame is one JSON object per line with a `"type"`
//! discriminator.  Decoding is strict: unknown frame types, unknown
//! keys, wrong value types, and out-of-range knobs are all errors —
//! a typo'd knob must fail the request, not silently run the default
//! point and return misleading numbers.
//!
//! Sweep points lower into [`SimSpec`], so a point carries exactly the
//! knobs (and hits exactly the validation) of the equivalent
//! `tardis run` invocation.

use anyhow::{anyhow, bail, Result};

use crate::api::SimSpec;
use crate::config::{
    Consistency, CoreModel, LeasePolicyKind, PdesMode, ProtocolKind, SocketInterleave,
};

use super::json::{self, Json};

/// Cap on points per sweep: keeps one hostile frame from queueing
/// unbounded work.  Real paper sweeps are 12 workloads x ~6 variants.
pub const MAX_POINTS: usize = 1024;

/// Cap on a batch id's length (it is echoed into every response).
pub const MAX_ID_LEN: usize = 128;

/// One decoded client frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Protocol handshake; server answers with its banner.
    Hello,
    /// Liveness probe; server answers `pong`.
    Ping,
    /// A batch of simulation points to fan across the worker pool.
    Sweep(SweepRequest),
    /// Graceful server shutdown: drain in-flight sessions, then exit.
    Shutdown,
}

/// A batched sweep: N independent points run concurrently, results
/// returned as one columnar payload.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRequest {
    /// Client-chosen batch id, echoed in every related server frame.
    pub id: String,
    /// Session seed applied to every point that doesn't set its own
    /// (per-session determinism: same id+seed+points -> same bits).
    pub seed: Option<u64>,
    /// Emit a `progress` frame every this many commits per point
    /// (0 = no progress frames).
    pub progress_every: u64,
    pub points: Vec<SimSpec>,
}

/// Decode one wire line into a [`Request`].
pub fn decode(line: &str) -> Result<Request> {
    let v = json::parse(line.trim()).map_err(|e| anyhow!("bad JSON: {e}"))?;
    let ty = v
        .get("type")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("frame needs a string \"type\" field"))?;
    match ty {
        "hello" => {
            expect_keys(&v, &["type"])?;
            Ok(Request::Hello)
        }
        "ping" => {
            expect_keys(&v, &["type"])?;
            Ok(Request::Ping)
        }
        "shutdown" => {
            expect_keys(&v, &["type"])?;
            Ok(Request::Shutdown)
        }
        "sweep" => Ok(Request::Sweep(decode_sweep(&v)?)),
        other => bail!("unknown frame type {other:?}"),
    }
}

fn decode_sweep(v: &Json) -> Result<SweepRequest> {
    expect_keys(v, &["type", "id", "seed", "progress_every", "points"])?;
    let id = v
        .get("id")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("sweep needs a string \"id\""))?
        .to_string();
    if id.is_empty() || id.len() > MAX_ID_LEN {
        bail!("sweep id must be 1..={MAX_ID_LEN} bytes");
    }
    let seed = opt_u64(v, "seed")?;
    let progress_every = opt_u64(v, "progress_every")?.unwrap_or(0);
    let points = v
        .get("points")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("sweep needs a \"points\" array"))?;
    if points.is_empty() {
        bail!("sweep has no points");
    }
    if points.len() > MAX_POINTS {
        bail!("sweep has {} points (max {MAX_POINTS})", points.len());
    }
    let points = points
        .iter()
        .enumerate()
        .map(|(i, p)| {
            decode_point(p, seed).map_err(|e| anyhow!("point {i}: {e}")).and_then(|spec| {
                // Full CLI-equivalent validation now, before anything
                // is queued: a sweep is accepted whole or not at all.
                spec.builder().map_err(|e| anyhow!("point {i}: {e}"))?;
                Ok(spec)
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(SweepRequest { id, seed, progress_every, points })
}

/// Every key a point object may carry; names match the `tardis run`
/// flags one-for-one.
const POINT_KEYS: &[&str] = &[
    "workload",
    "label",
    "protocol",
    "cores",
    "core_model",
    "consistency",
    "lease_policy",
    "sockets",
    "numa_ratio",
    "interleave",
    "lease",
    "self_inc",
    "delta_bits",
    "no_spec",
    "scale_down",
    "trace_len",
    "seed",
    "threads",
    "pdes_mode",
    "rebalance_every",
];

fn decode_point(v: &Json, session_seed: Option<u64>) -> Result<SimSpec> {
    if !matches!(v, Json::Obj(_)) {
        bail!("point must be an object");
    }
    expect_keys(v, POINT_KEYS)?;
    let workload = v
        .get("workload")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("point needs a string \"workload\""))?;
    let mut spec = SimSpec::new(workload);
    if let Some(l) = v.get("label") {
        spec.label =
            Some(l.as_str().ok_or_else(|| anyhow!("\"label\" must be a string"))?.to_string());
    }
    if let Some(p) = v.get("protocol") {
        let s = p.as_str().ok_or_else(|| anyhow!("\"protocol\" must be a string"))?;
        spec.protocol = ProtocolKind::parse(s)
            .ok_or_else(|| anyhow!("unknown protocol {s:?} (tardis, msi, ackwise)"))?;
    }
    if let Some(c) = opt_u32(v, "cores")? {
        spec.cores = c;
    }
    if let Some(m) = v.get("core_model") {
        let s = m.as_str().ok_or_else(|| anyhow!("\"core_model\" must be a string"))?;
        spec.core_model =
            CoreModel::parse(s).ok_or_else(|| anyhow!("unknown core_model {s:?} (inorder, ooo)"))?;
    }
    if let Some(c) = v.get("consistency") {
        let s = c.as_str().ok_or_else(|| anyhow!("\"consistency\" must be a string"))?;
        spec.consistency = Some(
            Consistency::parse(s).ok_or_else(|| anyhow!("unknown consistency {s:?} (sc, tso)"))?,
        );
    }
    if let Some(p) = v.get("lease_policy") {
        let s = p.as_str().ok_or_else(|| anyhow!("\"lease_policy\" must be a string"))?;
        spec.lease_policy = Some(LeasePolicyKind::parse(s).ok_or_else(|| {
            anyhow!("unknown lease_policy {s:?} (static, dynamic, predictive)")
        })?);
    }
    spec.sockets = opt_u32(v, "sockets")?;
    spec.numa_ratio = opt_u32(v, "numa_ratio")?;
    if let Some(i) = v.get("interleave") {
        let s = i.as_str().ok_or_else(|| anyhow!("\"interleave\" must be a string"))?;
        spec.interleave = Some(
            SocketInterleave::parse(s)
                .ok_or_else(|| anyhow!("unknown interleave {s:?} (line, block)"))?,
        );
    }
    spec.lease = opt_u64(v, "lease")?;
    spec.self_inc = opt_u64(v, "self_inc")?;
    spec.delta_bits = opt_u32(v, "delta_bits")?;
    if let Some(b) = v.get("no_spec") {
        spec.no_spec = b.as_bool().ok_or_else(|| anyhow!("\"no_spec\" must be a bool"))?;
    }
    if let Some(s) = opt_u32(v, "scale_down")? {
        if s == 0 {
            bail!("\"scale_down\" must be >= 1");
        }
        spec.scale_down = s;
    }
    spec.trace_len = opt_u32(v, "trace_len")?;
    // Point seed wins over the session seed; both are deterministic.
    spec.seed = opt_u64(v, "seed")?.or(session_seed);
    // Engine threads per point: a pure perf knob — results are
    // bit-for-bit identical to the serial run (tests/serve.rs).
    spec.threads = opt_u32(v, "threads")?;
    if let Some(m) = v.get("pdes_mode").filter(|j| !j.is_null()) {
        let s = m.as_str().ok_or_else(|| anyhow!("\"pdes_mode\" must be a string"))?;
        spec.pdes_mode = Some(
            PdesMode::parse(s)
                .ok_or_else(|| anyhow!("unknown pdes_mode {s:?} (epoch, nullmsg, auto)"))?,
        );
    }
    spec.rebalance_every = opt_u32(v, "rebalance_every")?;
    Ok(spec)
}

/// Reject any key outside `allowed` (null-valued keys count too — a
/// typo'd knob set to null is still a typo'd knob).
fn expect_keys(v: &Json, allowed: &[&str]) -> Result<()> {
    for k in v.keys() {
        if !allowed.contains(&k) {
            bail!("unknown key {k:?} (allowed: {})", allowed.join(", "));
        }
    }
    Ok(())
}

fn opt_u64(v: &Json, key: &str) -> Result<Option<u64>> {
    match v.get(key) {
        None => Ok(None),
        Some(j) if j.is_null() => Ok(None),
        Some(j) => {
            j.as_u64().map(Some).ok_or_else(|| anyhow!("{key:?} must be a non-negative integer"))
        }
    }
}

fn opt_u32(v: &Json, key: &str) -> Result<Option<u32>> {
    match v.get(key) {
        None => Ok(None),
        Some(j) if j.is_null() => Ok(None),
        Some(j) => {
            j.as_u32().map(Some).ok_or_else(|| anyhow!("{key:?} must be a u32 integer"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decodes_control_frames() {
        assert_eq!(decode(r#"{"type":"hello"}"#).unwrap(), Request::Hello);
        assert_eq!(decode(r#"{"type":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(decode(r#"{"type":"shutdown"}"#).unwrap(), Request::Shutdown);
    }

    #[test]
    fn decodes_a_full_sweep_point() {
        let line = r#"{"type":"sweep","id":"b1","seed":7,"progress_every":1000,
            "points":[{"workload":"fft","protocol":"msi","cores":16,
                       "core_model":"ooo","scale_down":8,"label":"msi-16"},
                      {"workload":"barnes","cores":4,"sockets":2,
                       "numa_ratio":3,"interleave":"block","trace_len":64,
                       "seed":99,"no_spec":true,"lease":8,"self_inc":16,
                       "delta_bits":20,"consistency":"tso",
                       "lease_policy":"dynamic"}]}"#;
        let Request::Sweep(s) = decode(line).unwrap() else { panic!("not a sweep") };
        assert_eq!(s.id, "b1");
        assert_eq!(s.seed, Some(7));
        assert_eq!(s.progress_every, 1000);
        assert_eq!(s.points.len(), 2);
        let p0 = &s.points[0];
        assert_eq!(p0.protocol, ProtocolKind::Msi);
        assert_eq!(p0.cores, 16);
        assert_eq!(p0.core_model, CoreModel::OutOfOrder);
        assert_eq!(p0.seed, Some(7), "session seed fills unset point seeds");
        assert_eq!(p0.variant_label(), "msi-16");
        let p1 = &s.points[1];
        assert_eq!(p1.seed, Some(99), "point seed wins over session seed");
        assert_eq!(p1.sockets, Some(2));
        assert!(p1.no_spec);
        assert_eq!(p1.consistency, Some(Consistency::Tso));
    }

    #[test]
    fn rejects_malformed_frames() {
        let cases: &[(&str, &str)] = &[
            ("not json at all", "bad JSON"),
            (r#"{"no_type":1}"#, "type"),
            (r#"{"type":"launch_missiles"}"#, "unknown frame type"),
            (r#"{"type":"ping","extra":1}"#, "unknown key"),
            (r#"{"type":"sweep","id":"b","points":[]}"#, "no points"),
            (r#"{"type":"sweep","id":"","points":[{"workload":"fft"}]}"#, "id must be"),
            (r#"{"type":"sweep","id":"b","points":[{"workload":"nope"}]}"#, "unknown workload"),
            (r#"{"type":"sweep","id":"b","points":[{"workload":"fft","corez":4}]}"#, "unknown key"),
            (
                r#"{"type":"sweep","id":"b","points":[{"workload":"fft","cores":"many"}]}"#,
                "must be a u32",
            ),
            (
                r#"{"type":"sweep","id":"b","points":[{"workload":"fft","numa_ratio":4}]}"#,
                "numa-ratio has no effect",
            ),
            (
                r#"{"type":"sweep","id":"b","points":[{"workload":"fft","threads":"two"}]}"#,
                "must be a u32",
            ),
            (
                r#"{"type":"sweep","id":"b","points":[{"workload":"fft","pdes_mode":"turbo"}]}"#,
                "unknown pdes_mode",
            ),
            (
                r#"{"type":"sweep","id":"b","points":[{"workload":"fft","rebalance_every":"x"}]}"#,
                "must be a u32",
            ),
            (
                r#"{"type":"sweep","id":"b","points":[{"workload":"fft","cores":0}]}"#,
                "at least one core",
            ),
            (
                r#"{"type":"sweep","id":"b","points":[{"workload":"fft","scale_down":0}]}"#,
                "scale_down",
            ),
            (r#"{"type":"sweep","id":"b","seed":-1,"points":[{"workload":"fft"}]}"#, "seed"),
        ];
        for (line, needle) in cases {
            let err = decode(line).unwrap_err().to_string();
            assert!(err.contains(needle), "{line} -> {err}");
        }
    }

    #[test]
    fn point_errors_name_the_offending_index() {
        let line = r#"{"type":"sweep","id":"b","points":[
            {"workload":"fft"},{"workload":"bogus"}]}"#;
        let err = decode(line).unwrap_err().to_string();
        assert!(err.contains("point 1:"), "{err}");
    }

    #[test]
    fn null_knobs_read_as_absent() {
        let line = r#"{"type":"sweep","id":"b","seed":null,
            "points":[{"workload":"fft","trace_len":null,"sockets":null}]}"#;
        let Request::Sweep(s) = decode(line).unwrap() else { panic!() };
        assert_eq!(s.seed, None);
        assert_eq!(s.points[0].trace_len, None);
        assert_eq!(s.points[0].sockets, None);
    }

    #[test]
    fn threads_knob_decodes_per_point() {
        let line = r#"{"type":"sweep","id":"b","points":[
            {"workload":"fft","cores":4,"threads":2},{"workload":"fft"}]}"#;
        let Request::Sweep(s) = decode(line).unwrap() else { panic!() };
        assert_eq!(s.points[0].threads, Some(2));
        assert_eq!(s.points[1].threads, None);
    }

    #[test]
    fn pdes_knobs_decode_per_point() {
        let line = r#"{"type":"sweep","id":"b","points":[
            {"workload":"fft","cores":4,"threads":2,"pdes_mode":"nullmsg",
             "rebalance_every":4},
            {"workload":"fft","pdes_mode":null}]}"#;
        let Request::Sweep(s) = decode(line).unwrap() else { panic!() };
        assert_eq!(s.points[0].pdes_mode, Some(PdesMode::NullMsg));
        assert_eq!(s.points[0].rebalance_every, Some(4));
        assert_eq!(s.points[1].pdes_mode, None, "null reads as absent");
        assert_eq!(s.points[1].rebalance_every, None);
    }
}
