//! Minimal JSON for the serve wire protocol.
//!
//! serde is not in this image's offline registry, so requests are
//! parsed by this small recursive-descent parser and responses are
//! hand-rolled by the frame/payload writers (exact `u64` formatting,
//! [`escape`] for strings).  The parser accepts one complete JSON
//! document per wire frame; objects keep insertion order and reject
//! duplicate keys (a duplicated knob in a request must not silently
//! win by position).

use std::fmt::Write as _;

/// Nesting cap: a control-plane frame never needs deep structure, and
/// the cap keeps hostile input from overflowing the parse stack.
const MAX_DEPTH: u32 = 32;

/// One parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All numbers parse as f64; integer accessors re-check
    /// integrality ([`Json::as_u64`]).
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object key list (empty for non-objects).
    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(fields) => fields.iter().map(|(k, _)| k.as_str()).collect(),
            _ => Vec::new(),
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer value.  f64 represents integers exactly
    /// up to 2^53, far beyond any wire-legal knob; fractional or
    /// negative numbers are rejected.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9_007_199_254_740_992.0 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_u64().and_then(|v| u32::try_from(v).ok())
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// Parse one complete JSON document (leading/trailing whitespace ok).
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self, depth: u32) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err("nesting too deep".into());
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected {:?} at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let n: f64 =
            text.parse().map_err(|_| format!("bad number {:?} at byte {}", text, start))?;
        if !n.is_finite() {
            return Err(format!("non-finite number at byte {start}"));
        }
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(c) if c < 0x20 => return Err("raw control byte in string".into()),
                Some(_) => {
                    // Copy one UTF-8 scalar (multi-byte sequences pass
                    // through unchanged; input is already &str-valid).
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, String> {
        let hi = self.hex4()?;
        // Surrogate pair: a high surrogate must be followed by \uDC00..
        if (0xD800..0xDC00).contains(&hi) {
            if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
                self.i += 2;
                let lo = self.hex4()?;
                if !(0xDC00..0xE000).contains(&lo) {
                    return Err("invalid low surrogate".into());
                }
                let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                return char::from_u32(c).ok_or_else(|| "invalid surrogate pair".into());
            }
            return Err("lone high surrogate".into());
        }
        if (0xDC00..0xE000).contains(&hi) {
            return Err("lone low surrogate".into());
        }
        char::from_u32(hi).ok_or_else(|| "invalid \\u escape".into())
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|e| e.to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape {s:?}"))?;
        self.i += 4;
        Ok(v)
    }

    fn object(&mut self, depth: u32) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields: Vec<(String, Json)> = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if fields.iter().any(|(k, _)| *k == key) {
                return Err(format!("duplicate key {key:?}"));
            }
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self, depth: u32) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
}

/// JSON-escape a string into a quoted literal (the writer-side dual
/// of the parser; response strings may echo arbitrary request text,
/// e.g. error messages quoting an unknown workload name).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
        let v = parse(r#"{"a": [1, 2], "b": {"c": "d"}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 2);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("d"));
        assert_eq!(v.keys(), vec!["a", "b"]);
    }

    #[test]
    fn integer_accessors_enforce_integrality() {
        assert_eq!(parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(parse("7.5").unwrap().as_u64(), None);
        assert_eq!(parse("-7").unwrap().as_u64(), None);
        assert_eq!(parse("4294967296").unwrap().as_u32(), None);
        assert_eq!(parse("4294967295").unwrap().as_u32(), Some(u32::MAX));
    }

    #[test]
    fn string_escapes_round_trip() {
        let v = parse(r#""a\"b\\c\nd\u0041\u00e9""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA\u{e9}"));
        // Surrogate pair -> astral char.
        assert_eq!(parse(r#""\ud83d\ude00""#).unwrap().as_str(), Some("\u{1F600}"));
        // escape() emits what parse() reads back.
        let nasty = "quote\" slash\\ newline\n tab\t ctrl\u{1} unicode\u{e9}";
        assert_eq!(parse(&escape(nasty)).unwrap().as_str(), Some(nasty));
    }

    #[test]
    fn malformed_documents_are_rejected() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated",
            "{\"a\":1,\"a\":2}", "[1] trailing", "\"\\ud800\"", "nan", "\"bad\u{1}ctrl\"",
        ] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
        // Depth bomb stops at the cap instead of blowing the stack.
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&deep).is_err());
    }

    #[test]
    fn accessors_are_type_strict() {
        let v = parse(r#"{"s":"x","n":1,"b":true,"z":null}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_u64(), None);
        assert_eq!(v.get("n").unwrap().as_str(), None);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.get("z").unwrap().is_null());
        assert!(v.get("missing").is_none());
    }
}
