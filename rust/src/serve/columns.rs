//! The `tardis-serve-v1` columnar result payload.
//!
//! A finished batch is returned as one JSON object with a `columns`
//! map: one array per field, all the same length, point `i` at index
//! `i` everywhere.  Column-major wins over row-per-point objects for
//! this workload because consumers are analytical — "plot `sim_cycles`
//! across the sweep", "sum `total_flits`" — and a column lands in
//! NumPy/pandas as one contiguous slice instead of a Python-level
//! gather over N dicts.  The field names mirror the `BENCH_*.json`
//! per-stat vocabulary (`tools/schema_common.py` holds the single
//! shared list), so the serve validator and the bench validator check
//! the same schema.

use std::fmt::Write as _;
use std::time::Duration;

use crate::api::SimSpec;
use crate::stats::SimStats;

use super::json::escape;

/// Wire schema identifier; bump on any incompatible payload change.
pub const SCHEMA: &str = "tardis-serve-v1";

/// One completed point: the spec it ran plus its outcome.
pub struct PointResult {
    pub spec: SimSpec,
    pub stats: SimStats,
    pub elapsed: Duration,
}

/// Per-batch bookkeeping echoed into the payload's `timing` object.
pub struct BatchTiming {
    /// Wall time from batch accept to last point done.
    pub wall: Duration,
    /// Worker-pool queue depth observed when the batch was submitted.
    pub queue_depth_at_submit: usize,
}

/// Render a finished batch as the `tardis-serve-v1` columnar JSON
/// object (no trailing newline; the frame layer adds it).
///
/// `results` must be in point-submission order — the column index IS
/// the point index.
pub fn payload(
    batch_id: &str,
    seed: Option<u64>,
    workers: usize,
    timing: &BatchTiming,
    results: &[PointResult],
) -> String {
    let mut out = String::with_capacity(1024 + results.len() * 512);
    out.push_str("{\"schema\": ");
    out.push_str(&escape(SCHEMA));
    let _ = write!(out, ", \"batch_id\": {}", escape(batch_id));
    match seed {
        Some(s) => {
            let _ = write!(out, ", \"seed\": {s}");
        }
        None => out.push_str(", \"seed\": null"),
    }
    let _ = write!(out, ", \"n_points\": {}", results.len());
    let _ = write!(out, ", \"workers\": {workers}");
    let _ = write!(
        out,
        ", \"timing\": {{\"wall_s\": {:.6}, \"queue_depth_at_submit\": {}}}",
        timing.wall.as_secs_f64(),
        timing.queue_depth_at_submit
    );
    out.push_str(", \"columns\": {");

    // Identity columns first: what ran.
    push_str_column(&mut out, "workload", results.iter().map(|r| r.spec.workload.as_str()), true);
    let variants: Vec<String> = results.iter().map(|r| r.spec.variant_label()).collect();
    push_str_column(&mut out, "variant", variants.iter().map(String::as_str), false);
    push_u64_column(&mut out, "cores", results.iter().map(|r| u64::from(r.spec.cores)));

    // One column per counter, in the stable SimStats::columns order.
    // Transpose: results are row-major (per point), the wire is
    // column-major (per stat).
    let rows: Vec<Vec<(&'static str, u64)>> = results.iter().map(|r| r.stats.columns()).collect();
    let template = SimStats::default().columns();
    for (s, (name, _)) in template.iter().enumerate() {
        push_u64_column(&mut out, name, rows.iter().map(|r| r[s].1));
    }

    // Per-point wall time last (float column).
    out.push_str(", ");
    out.push_str(&escape("wall_s"));
    out.push_str(": [");
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "{:.6}", r.elapsed.as_secs_f64());
    }
    out.push(']');

    out.push_str("}}");
    out
}

fn push_str_column<'a>(
    out: &mut String,
    name: &str,
    values: impl Iterator<Item = &'a str>,
    first: bool,
) {
    if !first {
        out.push_str(", ");
    }
    out.push_str(&escape(name));
    out.push_str(": [");
    for (i, v) in values.enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&escape(v));
    }
    out.push(']');
}

fn push_u64_column(out: &mut String, name: &str, values: impl Iterator<Item = u64>) {
    out.push_str(", ");
    out.push_str(&escape(name));
    out.push_str(": [");
    for (i, v) in values.enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "{v}");
    }
    out.push(']');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::json::{self, Json};

    fn fake_result(workload: &str, cores: u32, cycles: u64) -> PointResult {
        let mut spec = SimSpec::new(workload);
        spec.cores = cores;
        let stats = SimStats { cycles, memops: cycles / 2, ..SimStats::default() };
        PointResult { spec, stats, elapsed: Duration::from_millis(5) }
    }

    #[test]
    fn payload_parses_back_and_is_column_major() {
        let timing = BatchTiming { wall: Duration::from_millis(42), queue_depth_at_submit: 3 };
        let results =
            vec![fake_result("fft", 16, 1000), fake_result("barnes", 64, 2000)];
        let text = payload("batch-1", Some(7), 4, &timing, &results);
        let v = json::parse(&text).expect("payload must be valid JSON");
        assert_eq!(v.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(v.get("batch_id").unwrap().as_str(), Some("batch-1"));
        assert_eq!(v.get("seed").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("n_points").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("workers").unwrap().as_u64(), Some(4));
        let timing = v.get("timing").unwrap();
        assert!(timing.get("wall_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(timing.get("queue_depth_at_submit").unwrap().as_u64(), Some(3));

        let cols = v.get("columns").unwrap();
        let workload = cols.get("workload").unwrap().as_array().unwrap();
        assert_eq!(workload[0].as_str(), Some("fft"));
        assert_eq!(workload[1].as_str(), Some("barnes"));
        assert_eq!(
            cols.get("variant").unwrap().as_array().unwrap()[0].as_str(),
            Some("tardis")
        );
        let cores = cols.get("cores").unwrap().as_array().unwrap();
        assert_eq!(cores[0].as_u64(), Some(16));
        let cycles = cols.get("sim_cycles").unwrap().as_array().unwrap();
        assert_eq!(cycles[0].as_u64(), Some(1000));
        assert_eq!(cycles[1].as_u64(), Some(2000));

        // Every stat column exists, same length, plus the 4 identity/
        // timing columns.
        let stat_names: Vec<&str> =
            SimStats::default().columns().iter().map(|(n, _)| *n).collect();
        for name in &stat_names {
            let col = cols.get(name).unwrap_or_else(|| panic!("missing column {name}"));
            assert_eq!(col.as_array().unwrap().len(), 2, "{name}");
        }
        assert_eq!(cols.keys().len(), stat_names.len() + 4);
        assert_eq!(cols.get("wall_s").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn null_seed_and_empty_batch_are_representable() {
        let timing = BatchTiming { wall: Duration::ZERO, queue_depth_at_submit: 0 };
        let text = payload("b", None, 1, &timing, &[]);
        let v = json::parse(&text).unwrap();
        assert!(v.get("seed").unwrap().is_null());
        assert_eq!(v.get("n_points").unwrap().as_u64(), Some(0));
        // Even with zero points every column is present (empty).
        let cols = v.get("columns").unwrap();
        assert_eq!(
            cols.get("sim_cycles").unwrap(),
            &Json::Arr(vec![]),
            "stat columns survive an empty batch"
        );
    }

    #[test]
    fn hostile_batch_ids_are_escaped() {
        let timing = BatchTiming { wall: Duration::ZERO, queue_depth_at_submit: 0 };
        let text = payload("a\"b\\c\nd", None, 1, &timing, &[]);
        let v = json::parse(&text).unwrap();
        assert_eq!(v.get("batch_id").unwrap().as_str(), Some("a\"b\\c\nd"));
    }
}
