//! Simulation-as-a-service: the `tardis serve` batch sweep server
//! (DESIGN.md §10).
//!
//! A long-lived TCP server speaking newline-delimited JSON frames.
//! Clients submit batched sweeps; every point is an independent
//! [`SimSpec`](crate::api::SimSpec) `-> SimBuilder -> run` session
//! fanned across a shared [`WorkerPool`](crate::coordinator::WorkerPool),
//! progress streams back through the [`Observer`](crate::api::Observer)
//! registry, and a finished batch returns one columnar
//! `tardis-serve-v1` payload (one array per statistic — the
//! `BENCH_*.json` field vocabulary, see [`columns`]).
//!
//! Wire protocol (client -> server frame types): `hello`, `ping`,
//! `sweep`, `shutdown`.  Server -> client: `hello`, `pong`, `ack`,
//! `progress`, `point_done`, `result`, `error`, `bye`.  One JSON
//! object per line, UTF-8.  `python/client/` ships sync and async
//! reference clients.
//!
//! Determinism: a point's results are bit-for-bit identical to the
//! equivalent `tardis run` invocation — both lower through the same
//! `SimSpec`, and per-session seeds make distinct sessions
//! deterministic too (`tests/serve.rs`, `tests/determinism.rs`).

pub mod columns;
pub mod json;
pub mod request;
pub mod server;

pub use columns::{BatchTiming, PointResult, SCHEMA};
pub use request::{Request, SweepRequest};
pub use server::{run_batch, ServeConfig, Server};
