//! Quickstart: run one synthetic workload under Tardis and print the
//! headline statistics — the `SimBuilder` API in its smallest form.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tardis_dsm::api::SimBuilder;
use tardis_dsm::config::ProtocolKind;
use tardis_dsm::coordinator::experiments::base_cfg;
use tardis_dsm::runtime::{workload_or_synth, TraceRuntime};
use tardis_dsm::workloads;

fn main() -> anyhow::Result<()> {
    // The 12 SPLASH-2-signature benchmarks live in `workloads::all()`.
    // Materialize the trace once — through the AOT-compiled PJRT
    // artifact when available (`make artifacts` + `--features pjrt`),
    // else the bit-exact rust mirror — then run it under both
    // protocols through the builder.
    let spec = workloads::by_name("fft").expect("known workload");
    let mut runtime = TraceRuntime::open_default().ok();
    if runtime.is_none() {
        eprintln!("note: artifacts not found; using the rust mirror");
    }
    let n_cores = 16;
    let w = workload_or_synth(&mut runtime, n_cores, 2048, &spec.params);
    println!("workload fft on {n_cores} cores: {} operations", w.total_ops());
    for protocol in [ProtocolKind::Msi, ProtocolKind::Tardis] {
        let session = SimBuilder::from_config(base_cfg(n_cores, protocol)).workload(&w).build()?;
        println!("\n== {} ==", session.cfg().protocol.name());
        let res = session.run()?;
        let s = res.stats;
        println!("  cycles          {}", s.cycles);
        println!("  throughput      {:.4} memops/cycle", s.throughput());
        println!("  L1 miss rate    {:.2}%", s.l1_miss_rate() * 100.0);
        println!("  traffic         {} flits", s.traffic.total());
        println!("  renewals        {} ({} ok)", s.renew_requests, s.renew_success);
        println!("  invalidations   {}", s.invalidations_sent);
        println!("  ts incr rate    {:.0} cycles/ts", s.ts_incr_rate());
        println!("  wall time       {:.3?}", res.elapsed);
    }
    Ok(())
}
