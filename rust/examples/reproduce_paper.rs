//! End-to-end paper reproduction driver: regenerates EVERY table and
//! figure of the evaluation (Fig. 4–10, Tables VI and VII) from the
//! AOT-compiled trace artifacts through the full simulator stack, and
//! writes markdown + CSV into results/.
//!
//! ```sh
//! make artifacts && cargo run --release --example reproduce_paper
//! ```
//!
//! Scale note: full-length sweeps take tens of minutes; set
//! TARDIS_SCALE_DOWN=4 (etc.) to divide trace lengths for a quick pass.

use tardis_dsm::coordinator::experiments::{self, EvalCtx};
use tardis_dsm::coordinator::report::Table;
use tardis_dsm::runtime::TraceRuntime;

fn emit(table: &Table, stem: &str) -> anyhow::Result<()> {
    println!("\n{}", table.to_markdown());
    table.write("results", stem)?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let runtime = match TraceRuntime::open_default() {
        Ok(rt) => {
            println!("trace source: PJRT artifacts ({:?} configs)", rt.configs().len());
            Some(rt)
        }
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); falling back to the rust mirror");
            None
        }
    };
    let mut ctx = EvalCtx::new(runtime, 0);
    ctx.scale_down = std::env::var("TARDIS_SCALE_DOWN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    if ctx.scale_down > 1 {
        println!("scale-down factor {} (trace lengths divided)", ctx.scale_down);
    }

    let t0 = std::time::Instant::now();
    emit(&experiments::fig4(&mut ctx)?, "fig4")?;
    emit(&experiments::fig5(&mut ctx)?, "fig5")?;
    emit(&experiments::table6(&mut ctx)?, "table6")?;
    emit(&experiments::fig6(&mut ctx)?, "fig6")?;
    emit(&experiments::fig7(&mut ctx)?, "fig7")?;
    let (a, b) = experiments::fig8(&mut ctx)?;
    emit(&a, "fig8a")?;
    emit(&b, "fig8b")?;
    emit(&experiments::table7(), "table7")?;
    emit(&experiments::fig9(&mut ctx)?, "fig9")?;
    emit(&experiments::fig10(&mut ctx)?, "fig10")?;
    println!(
        "\nall tables and figures regenerated into results/ in {:.1?}",
        t0.elapsed()
    );
    Ok(())
}
