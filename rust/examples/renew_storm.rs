//! Renewal-storm exploration (paper §IV-A, Fig. 5): drive the VOLREND
//! signature — a large read-shared hot set plus synchronization — and
//! show how the renewal machinery behaves as the self-increment period
//! and lease vary, with and without speculation.

use tardis_dsm::api::SimBuilder;
use tardis_dsm::config::ProtocolKind;
use tardis_dsm::coordinator::experiments::base_cfg;
use tardis_dsm::runtime::{workload_or_synth, TraceRuntime};
use tardis_dsm::workloads;

fn main() -> anyhow::Result<()> {
    let spec = workloads::by_name("volrend").expect("volrend");
    let mut runtime = TraceRuntime::open_default().ok();
    let n_cores = 16;
    let w = workload_or_synth(&mut runtime, n_cores, 2048, &spec.params);

    println!("VOLREND signature on {n_cores} cores — the paper's renewal outlier");
    println!("(65.8% of its LLC requests are renewals at 64 cores)\n");

    let msi = SimBuilder::from_config(base_cfg(n_cores, ProtocolKind::Msi))
        .workload(&w)
        .run()?
        .stats;
    println!("MSI baseline: {} cycles, {} flits\n", msi.cycles, msi.traffic.total());

    println!(
        "{:>7} {:>6} {:>5} {:>9} {:>8} {:>9} {:>9} {:>8}",
        "period", "lease", "spec", "cycles", "thr/MSI", "renew%", "ok%", "traf/MSI"
    );
    for period in [10u64, 100, 1000] {
        for lease in [5u64, 10, 40] {
            for speculation in [true, false] {
                let s = SimBuilder::from_config(base_cfg(n_cores, ProtocolKind::Tardis))
                    .tardis(|t| {
                        t.self_inc_period = period;
                        t.lease = lease;
                        t.speculation = speculation;
                    })
                    .workload(&w)
                    .run()?
                    .stats;
                let ok = if s.renew_requests == 0 {
                    100.0
                } else {
                    100.0 * s.renew_success as f64 / s.renew_requests as f64
                };
                println!(
                    "{:>7} {:>6} {:>5} {:>9} {:>8.3} {:>8.1}% {:>8.1}% {:>8.3}",
                    period,
                    lease,
                    if speculation { "on" } else { "off" },
                    s.cycles,
                    msi.cycles as f64 / s.cycles as f64,
                    s.renew_rate() * 100.0,
                    ok,
                    s.traffic.total() as f64 / msi.traffic.total().max(1) as f64,
                );
            }
        }
    }
    println!("\nTakeaways (paper §VI-C): small periods renew aggressively;");
    println!("long leases trade renewals for staleness; speculation hides");
    println!("renew latency so the throughput gap closes when it is on.");
    Ok(())
}
