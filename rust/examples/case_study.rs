//! The paper's §V case study (Listing 2): run the 2-core program under
//! MSI and Tardis, print the committed operations with their logical
//! timestamps, and show the resulting global memory orders (paper
//! Listings 3 and 4) — including Tardis's "time traveling", where an
//! operation that commits later in physical time lands earlier in
//! physiological order.

use tardis_dsm::api::SimBuilder;
use tardis_dsm::config::ProtocolKind;
use tardis_dsm::prog::litmus;

fn main() -> anyhow::Result<()> {
    let w = litmus::case_study();
    println!("Program (paper Listing 2):");
    println!("  [Core 0]          [Core 1]");
    println!("  L(B)              nop");
    println!("  A = 1             B = 2");
    println!("  L(A)              L(A)");
    println!("  L(B)              B = 4");
    println!("  A = 3\n");

    for protocol in [ProtocolKind::Msi, ProtocolKind::Tardis] {
        let res = SimBuilder::small(2, protocol).workload(&w).run()?;
        println!("== {} == finished in {} cycles", protocol.name(), res.stats.cycles);
        println!("  {:>5}  {:>4}  {:>2}  {:>9}  {:>10}  {:>3}", "cycle", "core", "pc", "op", "value", "ts");
        for r in res.log.records.iter().filter(|r| r.valid) {
            let (op, value) = match (r.value_read, r.value_written) {
                (Some(v), None) => ("load", v),
                (None, Some(v)) => ("store", v),
                (Some(_), Some(v)) => ("atomic", v),
                _ => continue,
            };
            let name = match r.addr {
                a if a == litmus::A => "A",
                a if a == litmus::B => "B",
                _ => "?",
            };
            println!(
                "  {:>5}  {:>4}  {:>2}  {:>6}({})  {:>10}  {:>3}",
                r.commit_cycle, r.core, r.pc, op, name, value, r.ts
            );
        }

        // Global memory order = sort by the physiological key.
        let mut order: Vec<_> = res.log.records.iter().filter(|r| r.valid).collect();
        order.sort_by_key(|r| r.key());
        let render: Vec<String> = order
            .iter()
            .map(|r| {
                let name = if r.addr == litmus::A { "A" } else { "B" };
                if r.value_written.is_some() {
                    format!("S{}({name})", r.core)
                } else {
                    format!("L{}({name})", r.core)
                }
            })
            .collect();
        println!("  global memory order: {}\n", render.join(" < "));
    }
    println!("Note how Tardis may order core 0's second L(B) before both");
    println!("stores to B (paper Listing 4) — physiological time travel.");
    Ok(())
}
