//! Run the litmus suite (SB, MP, LB, IRIW, CO) under all three
//! protocols and both core models with interleaving jitter, reporting
//! outcome histograms and confirming no forbidden outcome appears.

use std::collections::HashMap;

use tardis_dsm::api::SimBuilder;
use tardis_dsm::config::{CoreModel, ProtocolKind};
use tardis_dsm::prog::{checker, litmus, Op, Workload};
use tardis_dsm::testutil::Rng;

fn jitter(w: &Workload, seed: u64) -> Workload {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1);
    let mut w = w.clone();
    for p in &mut w.programs {
        for op in &mut p.ops {
            match op {
                Op::Load { gap, .. } | Op::Store { gap, .. } => *gap = rng.below(12) as u32,
                _ => {}
            }
        }
    }
    w
}

fn main() -> anyhow::Result<()> {
    const RUNS: u64 = 100;
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
            println!("== {} / {:?} ==", protocol.name(), model);
            for lt in litmus::all() {
                let mut outcomes: HashMap<Vec<u64>, u32> = HashMap::new();
                let mut forbidden = 0;
                for seed in 0..RUNS {
                    let w = jitter(&lt.workload, seed);
                    let res = SimBuilder::small(w.n_cores(), protocol)
                        .core_model(model)
                        .workload(&w)
                        .run()?;
                    checker::check(&res.log)
                        .map_err(|v| anyhow::anyhow!("{}: SC violation {v:?}", lt.name))?;
                    let out: Vec<u64> = lt
                        .observed
                        .iter()
                        .map(|&(core, pc)| {
                            res.log
                                .records
                                .iter()
                                .find(|r| {
                                    r.valid && r.core == core && r.pc == pc && r.value_read.is_some()
                                })
                                .map(|r| r.value_read.unwrap())
                                .unwrap_or(u64::MAX)
                        })
                        .collect();
                    if !(lt.allowed)(&out) {
                        forbidden += 1;
                    }
                    *outcomes.entry(out).or_insert(0) += 1;
                }
                let mut hist: Vec<_> = outcomes.into_iter().collect();
                hist.sort();
                let render: Vec<String> =
                    hist.iter().map(|(o, n)| format!("{o:?}x{n}")).collect();
                println!(
                    "  {:<5} forbidden={}  outcomes: {}",
                    lt.name,
                    forbidden,
                    render.join(" ")
                );
                assert_eq!(forbidden, 0, "{} produced a forbidden outcome!", lt.name);
            }
        }
    }
    println!("\nall litmus tests clean — no forbidden SC outcome in any run");
    Ok(())
}
