"""Reference clients for the `tardis serve` batch sweep server.

The server speaks newline-delimited JSON over TCP (DESIGN.md §10):
submit a batch of simulation points, stream progress, and fetch the
results as a columnar ``tardis-serve-v1`` payload — one list per
statistic, so ``fetch_columns()["sim_cycles"]`` drops straight into
NumPy/pandas without a row-wise gather.

Two clients, one protocol:

* :class:`client.sync.TardisClient` — blocking sockets, the default.
* :class:`client.aio.AsyncTardisClient` — asyncio streams.

Both accept injected transports, so the unit tests (and any consumer
that wants to replay recorded frames) run without a live server.
"""

from .frames import (
    SCHEMA,
    ProtocolError,
    ServerError,
    decode_frame,
    encode_frame,
    validate_payload,
)
from .sync import TardisClient
from .aio import AsyncTardisClient

__all__ = [
    "SCHEMA",
    "ProtocolError",
    "ServerError",
    "decode_frame",
    "encode_frame",
    "validate_payload",
    "TardisClient",
    "AsyncTardisClient",
]
