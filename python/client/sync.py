"""Blocking reference client for `tardis serve`.

Typical use::

    from client import TardisClient

    with TardisClient(port=7436) as c:
        bid = c.submit_sweep(
            [{"workload": "fft", "cores": 16},
             {"workload": "barnes", "cores": 16, "protocol": "msi"}],
            seed=7, progress_every=100_000)
        for ev in c.iter_progress(bid):
            print(ev)                      # progress / point_done frames
        cols = c.fetch_columns(bid)        # dict of equal-length lists
        print(cols["workload"], cols["sim_cycles"])
"""

import itertools
import socket

from . import frames
from .frames import ProtocolError


class TardisClient:
    """One TCP connection to a `tardis serve` server.

    Pass ``sock`` to inject a transport: anything with ``sendall``,
    ``makefile("rb")``, and ``close`` (the unit tests use a recorded-
    frame fake; a live ``socket.socket`` works unchanged).
    """

    def __init__(self, host="127.0.0.1", port=7436, timeout=120.0, sock=None):
        if sock is None:
            sock = socket.create_connection((host, port), timeout=timeout)
        self._sock = sock
        self._rfile = sock.makefile("rb")
        self._ids = itertools.count(1)
        # Frames already read while draining a different batch, keyed
        # by batch id ("result" frames only — chatter is not buffered).
        self._results = {}

    # ------------------------------------------------------ transport

    def close(self):
        try:
            self._rfile.close()
        finally:
            self._sock.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def _send(self, obj):
        self._sock.sendall(frames.encode_frame(obj))

    def _recv(self):
        line = self._rfile.readline()
        if not line:
            raise ProtocolError("server closed the connection")
        return frames.decode_frame(line)

    # ------------------------------------------------------- protocol

    def hello(self):
        """Handshake; returns the server banner frame."""
        self._send({"type": "hello"})
        frame = frames.raise_if_error(self._recv())
        if frame.get("type") != "hello":
            raise ProtocolError(f"expected hello, got {frame!r}")
        return frame

    def ping(self):
        self._send({"type": "ping"})
        frame = frames.raise_if_error(self._recv())
        if frame.get("type") != "pong":
            raise ProtocolError(f"expected pong, got {frame!r}")

    def submit_sweep(self, points, batch_id=None, seed=None, progress_every=0):
        """Submit a batch; blocks until the server acks; returns the
        batch id (auto-generated when not given)."""
        if batch_id is None:
            batch_id = f"batch-{next(self._ids)}"
        self._send(frames.sweep_frame(points, batch_id, seed, progress_every))
        ack = frames.raise_if_error(self._recv())
        if ack.get("type") != "ack" or ack.get("batch_id") != batch_id:
            raise ProtocolError(f"expected ack for {batch_id!r}, got {ack!r}")
        return batch_id

    def iter_progress(self, batch_id):
        """Yield ``progress`` and ``point_done`` frames for ``batch_id``
        until its result (or error) arrives; terminal frames are
        buffered for :meth:`fetch_columns`.  Raises
        :class:`ServerError` immediately on a batch failure."""
        while True:
            stored = self._results.get(batch_id)
            if stored is not None:
                frames.raise_if_error(stored)
                return
            frame = self._recv()
            ty = frame.get("type")
            bid = frame.get("batch_id")
            if ty in ("result", "error") and bid is not None:
                self._results[bid] = frame  # terminal; maybe not ours
            elif ty == "error":
                frames.raise_if_error(frame)  # connection-level error
            elif ty in ("progress", "point_done") and bid == batch_id:
                yield frame

    def fetch_columns(self, batch_id):
        """Block until ``batch_id``'s result and return its validated
        ``columns`` dict-of-lists (point ``i`` at index ``i`` of every
        list)."""
        payload = self.fetch_payload(batch_id)
        return frames.validate_payload(payload)

    def fetch_payload(self, batch_id):
        """Like :meth:`fetch_columns` but returns the whole payload
        (schema, seed, workers, timing, columns), unvalidated."""
        for _ in self.iter_progress(batch_id):
            pass  # drain chatter; iter_progress stops at the result
        frame = frames.raise_if_error(self._results.pop(batch_id))
        payload = frame.get("payload")
        if not isinstance(payload, dict):
            raise ProtocolError(f"result for {batch_id!r} has no payload")
        return payload

    def shutdown(self):
        """Ask the server to drain in-flight sessions and exit; reads
        until ``bye`` (or EOF)."""
        self._send({"type": "shutdown"})
        try:
            while True:
                if frames.raise_if_error(self._recv()).get("type") == "bye":
                    return
        except ProtocolError:
            return  # EOF before bye: the server is gone either way
