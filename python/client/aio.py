"""Asyncio reference client for `tardis serve`.

Mirrors :class:`client.sync.TardisClient` method-for-method with
coroutines; ``iter_progress`` is an async generator::

    from client import AsyncTardisClient

    async with await AsyncTardisClient.connect(port=7436) as c:
        bid = await c.submit_sweep([{"workload": "fft"}], progress_every=10_000)
        async for ev in c.iter_progress(bid):
            print(ev)
        cols = await c.fetch_columns(bid)
"""

import asyncio
import itertools

from . import frames
from .frames import ProtocolError


class AsyncTardisClient:
    """One connection over asyncio streams.

    Construct with :meth:`connect`, or inject ``(reader, writer)``
    directly — the tests feed a plain ``asyncio.StreamReader`` with
    recorded frames and a no-op writer.
    """

    def __init__(self, reader, writer):
        self._reader = reader
        self._writer = writer
        self._ids = itertools.count(1)
        self._results = {}

    @classmethod
    async def connect(cls, host="127.0.0.1", port=7436):
        reader, writer = await asyncio.open_connection(host, port)
        return cls(reader, writer)

    # ------------------------------------------------------ transport

    async def close(self):
        self._writer.close()
        wait = getattr(self._writer, "wait_closed", None)
        if wait is not None:
            await wait()

    async def __aenter__(self):
        return self

    async def __aexit__(self, *exc):
        await self.close()
        return False

    async def _send(self, obj):
        self._writer.write(frames.encode_frame(obj))
        drain = getattr(self._writer, "drain", None)
        if drain is not None:
            await drain()

    async def _recv(self):
        line = await self._reader.readline()
        if not line:
            raise ProtocolError("server closed the connection")
        return frames.decode_frame(line)

    # ------------------------------------------------------- protocol

    async def hello(self):
        await self._send({"type": "hello"})
        frame = frames.raise_if_error(await self._recv())
        if frame.get("type") != "hello":
            raise ProtocolError(f"expected hello, got {frame!r}")
        return frame

    async def ping(self):
        await self._send({"type": "ping"})
        frame = frames.raise_if_error(await self._recv())
        if frame.get("type") != "pong":
            raise ProtocolError(f"expected pong, got {frame!r}")

    async def submit_sweep(self, points, batch_id=None, seed=None,
                           progress_every=0):
        if batch_id is None:
            batch_id = f"batch-{next(self._ids)}"
        await self._send(
            frames.sweep_frame(points, batch_id, seed, progress_every))
        ack = frames.raise_if_error(await self._recv())
        if ack.get("type") != "ack" or ack.get("batch_id") != batch_id:
            raise ProtocolError(f"expected ack for {batch_id!r}, got {ack!r}")
        return batch_id

    async def iter_progress(self, batch_id):
        while True:
            stored = self._results.get(batch_id)
            if stored is not None:
                frames.raise_if_error(stored)
                return
            frame = await self._recv()
            ty = frame.get("type")
            bid = frame.get("batch_id")
            if ty in ("result", "error") and bid is not None:
                self._results[bid] = frame
            elif ty == "error":
                frames.raise_if_error(frame)
            elif ty in ("progress", "point_done") and bid == batch_id:
                yield frame

    async def fetch_columns(self, batch_id):
        payload = await self.fetch_payload(batch_id)
        return frames.validate_payload(payload)

    async def fetch_payload(self, batch_id):
        async for _ in self.iter_progress(batch_id):
            pass
        frame = frames.raise_if_error(self._results.pop(batch_id))
        payload = frame.get("payload")
        if not isinstance(payload, dict):
            raise ProtocolError(f"result for {batch_id!r} has no payload")
        return payload

    async def shutdown(self):
        await self._send({"type": "shutdown"})
        try:
            while True:
                frame = frames.raise_if_error(await self._recv())
                if frame.get("type") == "bye":
                    return
        except ProtocolError:
            return
