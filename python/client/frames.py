"""Wire-frame helpers shared by the sync and async clients.

One frame = one JSON object on one line, UTF-8, ``\\n``-terminated.
Client frame types: ``hello``, ``ping``, ``sweep``, ``shutdown``.
Server frame types: ``hello``, ``pong``, ``ack``, ``progress``,
``point_done``, ``result``, ``error``, ``bye``.
"""

import json

#: The columnar result schema this client understands.
SCHEMA = "tardis-serve-v1"

#: Columns that identify a point (everything else is a counter).
IDENTITY_COLUMNS = ("workload", "variant", "cores")


class ProtocolError(Exception):
    """The peer violated the wire protocol (bad frame, bad payload,
    unexpected EOF)."""


class ServerError(Exception):
    """The server reported an ``error`` frame for our request."""

    def __init__(self, message, batch_id=None):
        super().__init__(message)
        self.batch_id = batch_id


def encode_frame(obj):
    """Serialize one frame to its wire bytes (newline-terminated)."""
    if not isinstance(obj, dict) or not isinstance(obj.get("type"), str):
        raise ProtocolError("a frame is a dict with a string 'type'")
    line = json.dumps(obj, separators=(",", ":"), ensure_ascii=False)
    if "\n" in line:  # impossible via json.dumps, but the invariant matters
        raise ProtocolError("frame serialized to multiple lines")
    return (line + "\n").encode("utf-8")


def decode_frame(line):
    """Parse one wire line (bytes or str) into a frame dict."""
    if isinstance(line, bytes):
        line = line.decode("utf-8", errors="replace")
    try:
        obj = json.loads(line)
    except ValueError as e:
        raise ProtocolError(f"bad frame JSON: {e}") from None
    if not isinstance(obj, dict) or not isinstance(obj.get("type"), str):
        raise ProtocolError(f"frame is not a typed object: {line!r}")
    return obj


def raise_if_error(frame):
    """Turn a server ``error`` frame into a :class:`ServerError`."""
    if frame.get("type") == "error":
        raise ServerError(frame.get("message", "unknown server error"),
                          batch_id=frame.get("batch_id"))
    return frame


def sweep_frame(points, batch_id, seed=None, progress_every=0):
    """Build a ``sweep`` request frame.

    ``points`` is a list of dicts whose keys mirror the ``tardis run``
    flags (``workload`` required; ``protocol``, ``cores``, ``seed``,
    ...).  Validation is the server's job — the client passes points
    through untouched so server-side errors stay authoritative.
    """
    if not isinstance(points, (list, tuple)) or not points:
        raise ProtocolError("a sweep needs a non-empty list of points")
    frame = {
        "type": "sweep",
        "id": batch_id,
        "seed": seed,
        "progress_every": int(progress_every),
        "points": list(points),
    }
    return frame


def validate_payload(payload):
    """Check a ``tardis-serve-v1`` payload's envelope and columnar
    invariants; returns the ``columns`` dict-of-lists.

    Raises :class:`ProtocolError` on schema mismatch, missing identity
    columns, non-list columns, or ragged column lengths.  (Exhaustive
    per-column schema checking lives server-side in
    ``tools/validate_serve.py``; this guards what consumers index.)
    """
    if not isinstance(payload, dict):
        raise ProtocolError("payload is not an object")
    if payload.get("schema") != SCHEMA:
        raise ProtocolError(
            f"schema mismatch: got {payload.get('schema')!r}, want {SCHEMA!r}")
    n = payload.get("n_points")
    if not isinstance(n, int) or n < 0:
        raise ProtocolError(f"bad n_points: {n!r}")
    columns = payload.get("columns")
    if not isinstance(columns, dict) or not columns:
        raise ProtocolError("payload has no columns")
    for name in IDENTITY_COLUMNS + ("sim_cycles", "wall_s"):
        if name not in columns:
            raise ProtocolError(f"missing column {name!r}")
    for name, col in columns.items():
        if not isinstance(col, list):
            raise ProtocolError(f"column {name!r} is not a list")
        if len(col) != n:
            raise ProtocolError(
                f"ragged column {name!r}: {len(col)} values for {n} points")
    return columns
