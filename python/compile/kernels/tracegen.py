"""L1 Pallas kernel: synthetic-workload memory-trace generation.

The simulator's input is a tensor of per-core memory operations.  For a
64-core x 4096-slot trace that is ~786k generated tuples per workload;
generation is the data-parallel hot spot of the compile path and is
implemented as a Pallas kernel: a counter-based xxhash-style PRNG plus
address-pattern synthesis evaluated per (core, slot) tile.

The kernel is deterministic in (params, core, slot): the pure-jnp oracle
in ref.py must produce bit-identical output, which pytest/hypothesis
enforce across shapes and parameter vectors.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the
(cores, slots) plane in (8, 128) VMEM blocks; all math is elementwise
uint32 VPU work, no MXU.  On this CPU image the kernel always runs with
interpret=True (real TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import spec

# xxhash/murmur-style 32-bit finalizer constants.
_K_CORE = 0x85EBCA6B
_K_SLOT = 0xC2B2AE35
_K_STREAM = 0x27D4EB2F
_M1 = 0x2C1B3C6D
_M2 = 0x297A2D39


def _mix(seed, core, slot, stream):
    """Counter-based PRNG: finalizer-style avalanche over (core, slot, stream)."""
    h = (
        seed
        ^ (core * jnp.uint32(_K_CORE))
        ^ (slot * jnp.uint32(_K_SLOT))
        ^ (stream * jnp.uint32(_K_STREAM))
    )
    h = h ^ (h >> 15)
    h = h * jnp.uint32(_M1)
    h = h ^ (h >> 12)
    h = h * jnp.uint32(_M2)
    h = h ^ (h >> 15)
    return h


def _gen_tile(params, core, slot, trace_len, n_cores):
    """Generate (op, addr, aux) for a tile of (core, slot) pairs.

    `core` and `slot` are uint32 arrays of identical shape; `params` is
    the int32[16] parameter vector (see kernels/spec.py); `trace_len`
    is the static trace length (needed to suppress lock episodes that
    cannot complete before the join barrier).  Returns three int32
    arrays of the tile shape.
    """
    u32 = lambda x: x.astype(jnp.uint32) if hasattr(x, "astype") else jnp.uint32(x)
    p = lambda idx: u32(params[idx])

    seed = p(spec.P_SEED)
    pattern = p(spec.P_PATTERN)
    priv_lines = jnp.maximum(p(spec.P_PRIV_LINES), 1)
    shared_lines = jnp.maximum(p(spec.P_SHARED_LINES), 1)
    pct_shared = p(spec.P_PCT_SHARED)
    pct_w_sh = p(spec.P_PCT_WRITE_SHARED)
    pct_w_pr = p(spec.P_PCT_WRITE_PRIV)
    sync_kind = p(spec.P_SYNC_KIND)
    sync_period = p(spec.P_SYNC_PERIOD)
    crit_len = p(spec.P_CRIT_LEN)
    n_locks = jnp.maximum(p(spec.P_N_LOCKS), 1)
    gap_max = p(spec.P_COMPUTE_GAP)
    stride = jnp.maximum(p(spec.P_STRIDE), 1)
    grid_dim = jnp.maximum(p(spec.P_GRID_DIM), 1)
    barrier_period = p(spec.P_BARRIER_PERIOD)

    h0 = _mix(seed, core, slot, jnp.uint32(0))
    h1 = _mix(seed, core, slot, jnp.uint32(1))
    h2 = _mix(seed, core, slot, jnp.uint32(2))
    h3 = _mix(seed, core, slot, jnp.uint32(3))
    h4 = _mix(seed, core, slot, jnp.uint32(4))
    h5 = _mix(seed, core, slot, jnp.uint32(5))
    h6 = _mix(seed, core, slot, jnp.uint32(6))

    # --- Barrier slots (sync_kind bit1): every barrier_period-th slot. ---
    use_barriers = (sync_kind & 2) != 0
    bp = jnp.maximum(barrier_period, 1)
    is_barrier = use_barriers & (barrier_period > 0) & (((slot + 1) % bp) == 0)
    barrier_epoch = (slot + 1) // bp

    # --- Lock episodes (sync_kind bit0): slot position within a period. ---
    use_locks = (sync_kind & 1) != 0
    sp = jnp.maximum(sync_period, 1)
    # An episode must fit inside its period: LOCK at m==0, UNLOCK at
    # m==crit_len+1 < sp.
    crit_len = jnp.minimum(crit_len, sp - jnp.minimum(sp, 2))
    m = slot % sp
    episode_start = slot - m
    lock_id = _mix(seed, core, episode_start, jnp.uint32(7)) % n_locks
    episode_end = episode_start + crit_len + 1
    # Deadlock guards: (a) the episode completes before the join barrier
    # and does not start at the warm-up slot 0; (b) no barrier slot
    # falls inside [episode_start, episode_end] while a lock is held.
    fits = (episode_start >= 1) & (episode_end <= jnp.uint32(trace_len - 2))
    first_bar = bp * ((episode_start + bp) // bp) - 1
    no_bar_inside = jnp.logical_not(
        use_barriers & (barrier_period > 0) & (first_bar <= episode_end)
    )
    in_lock_mode = use_locks & (sync_period > 0) & fits & no_bar_inside
    is_lock = in_lock_mode & (m == 0)
    is_unlock = in_lock_mode & (m == crit_len + 1)
    is_crit = in_lock_mode & (m >= 1) & (m <= crit_len)
    lock_addr = jnp.uint32(spec.LOCK_BASE) + lock_id
    crit_addr = (
        jnp.uint32(spec.LOCK_DATA_BASE)
        + lock_id * jnp.uint32(spec.LOCK_DATA_SPAN)
        + h3 % jnp.uint32(spec.LOCK_DATA_SPAN)
    )
    crit_store = (h2 % jnp.uint32(1000)) < jnp.uint32(500)

    # --- Normal slots: shared-heap vs private access. ---
    is_shared = (h0 % jnp.uint32(1000)) < pct_shared
    sh_store = (h1 % jnp.uint32(1000)) < pct_w_sh
    pr_store = (h1 % jnp.uint32(1000)) < pct_w_pr

    # Shared address by pattern.
    s_uniform = h5 % shared_lines
    # Strided (FFT/RADIX butterfly): reads sweep the whole array;
    # writes land in the core's own 1/N output partition (SPLASH-2
    # kernels write core-partitioned data).
    part = jnp.maximum(shared_lines // jnp.uint32(n_cores), 1)
    s_strided_rd = (slot * stride + core) % shared_lines
    s_strided_wr = (core * part + (slot * stride) % part) % shared_lines
    s_strided = jnp.where(sh_store, s_strided_wr, s_strided_rd)
    blk = jnp.maximum(shared_lines // jnp.uint32(spec.N_BLOCKS), 1)
    own_block = core % jnp.uint32(spec.N_BLOCKS)
    rd_block = h5 % jnp.uint32(spec.N_BLOCKS)
    block_sel = jnp.where(sh_store, own_block, rd_block)
    s_blocked = (block_sel * blk + h6 % blk) % shared_lines
    # Stencil (OCEAN): reads touch the core's own row and its
    # neighbors; writes only the core's own row (each core owns a band
        # of the grid).
    row = core % grid_dim
    drow = h5 % jnp.uint32(3)  # 0,1,2 -> -1,0,+1 via (row + dim + d - 1)
    row2 = (row + grid_dim + drow - 1) % grid_dim
    row_sel = jnp.where(sh_store, row, row2)
    s_stencil = (row_sel * grid_dim + h6 % grid_dim) % shared_lines
    hot = jnp.minimum(shared_lines, jnp.uint32(spec.HOT_SET_LINES))
    s_hot = h5 % hot

    s = s_uniform
    s = jnp.where(pattern == 1, s_strided, s)
    s = jnp.where(pattern == 2, s_blocked, s)
    s = jnp.where(pattern == 3, s_stencil, s)
    s = jnp.where(pattern == 4, s_hot, s)
    shared_addr = jnp.uint32(spec.SHARED_BASE) + s

    # Private accesses have temporal locality: 80% hit a hot 1/8
    # subset of the region (benchmark-like L1 hit rates; uniform
    # addressing would make every workload memory-bound).
    hot_priv = jnp.maximum(priv_lines // jnp.uint32(8), 1)
    priv_idx = jnp.where(
        (h6 % jnp.uint32(1000)) < jnp.uint32(800), h3 % hot_priv, h3 % priv_lines
    )
    priv_addr = (
        jnp.uint32(spec.PRIV_BASE)
        + core * jnp.uint32(spec.PRIV_STRIDE)
        + priv_idx
    )

    normal_store = jnp.where(is_shared, sh_store, pr_store)
    normal_addr = jnp.where(is_shared, shared_addr, priv_addr)
    normal_op = jnp.where(
        normal_store, jnp.uint32(spec.OP_STORE), jnp.uint32(spec.OP_LOAD)
    )

    # --- Compose with priority: barrier > lock > unlock > crit > normal. ---
    op = normal_op
    addr = normal_addr
    op = jnp.where(
        is_crit,
        jnp.where(crit_store, jnp.uint32(spec.OP_STORE), jnp.uint32(spec.OP_LOAD)),
        op,
    )
    addr = jnp.where(is_crit, crit_addr, addr)
    op = jnp.where(is_unlock, jnp.uint32(spec.OP_UNLOCK), op)
    addr = jnp.where(is_unlock, lock_addr, addr)
    op = jnp.where(is_lock, jnp.uint32(spec.OP_LOCK), op)
    addr = jnp.where(is_lock, lock_addr, addr)
    op = jnp.where(is_barrier, jnp.uint32(spec.OP_BARRIER), op)
    addr = jnp.where(is_barrier, jnp.uint32(spec.BARRIER_BASE), addr)

    gap = h4 % (gap_max + 1)
    is_memop = (op == spec.OP_LOAD) | (op == spec.OP_STORE)
    aux = jnp.where(is_memop, gap, jnp.uint32(0))
    aux = jnp.where(is_barrier, barrier_epoch, aux)

    return op.astype(jnp.int32), addr.astype(jnp.int32), aux.astype(jnp.int32)


def _kernel(params_ref, op_ref, addr_ref, aux_ref, *, block_cores,
            block_slots, trace_len, n_cores):
    """Pallas kernel body: one (block_cores, block_slots) tile per grid step."""
    pc = pl.program_id(0)
    ps = pl.program_id(1)
    core0 = (pc * block_cores).astype(jnp.uint32)
    slot0 = (ps * block_slots).astype(jnp.uint32)
    core = core0 + jax.lax.broadcasted_iota(
        jnp.uint32, (block_cores, block_slots), 0
    )
    slot = slot0 + jax.lax.broadcasted_iota(
        jnp.uint32, (block_cores, block_slots), 1
    )
    op, addr, aux = _gen_tile(params_ref[...], core, slot, trace_len, n_cores)
    op_ref[...] = op
    addr_ref[...] = addr
    aux_ref[...] = aux


def tracegen(params, n_cores, trace_len, *, interpret=True):
    """Generate the trace tensor int32[n_cores, trace_len, 3].

    `params` is the int32[16] parameter vector.  Shapes are static:
    one AOT artifact is produced per (n_cores, trace_len) configuration.
    """
    block_cores = min(8, n_cores)
    block_slots = min(128, trace_len)
    assert n_cores % block_cores == 0, "n_cores must tile by 8 (or be < 8)"
    assert trace_len % block_slots == 0, "trace_len must tile by 128"
    grid = (n_cores // block_cores, trace_len // block_slots)
    out_shape = jax.ShapeDtypeStruct((n_cores, trace_len), jnp.int32)

    op, addr, aux = pl.pallas_call(
        functools.partial(
            _kernel, block_cores=block_cores, block_slots=block_slots,
            trace_len=trace_len, n_cores=n_cores,
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((spec.N_PARAMS,), lambda i, j: (0,))],
        out_specs=[
            pl.BlockSpec((block_cores, block_slots), lambda i, j: (i, j)),
            pl.BlockSpec((block_cores, block_slots), lambda i, j: (i, j)),
            pl.BlockSpec((block_cores, block_slots), lambda i, j: (i, j)),
        ],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=interpret,
    )(params)
    return jnp.stack([op, addr, aux], axis=-1)
