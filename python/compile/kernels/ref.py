"""Pure-jnp oracle for the tracegen Pallas kernel.

Independent re-implementation of the trace-generation contract
(kernels/spec.py) as one whole-array jnp computation — no pallas, no
tiling.  pytest + hypothesis assert bit-identical output against
kernels/tracegen.py across shapes and parameter vectors; this file is
the correctness spec for the kernel's blocking/indexing.
"""

import jax
import jax.numpy as jnp

from . import spec


def _mix_ref(seed, core, slot, stream):
    h = (
        seed
        ^ (core * jnp.uint32(0x85EBCA6B))
        ^ (slot * jnp.uint32(0xC2B2AE35))
        ^ (stream * jnp.uint32(0x27D4EB2F))
    )
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x2C1B3C6D)
    h = h ^ (h >> 12)
    h = h * jnp.uint32(0x297A2D39)
    h = h ^ (h >> 15)
    return h


def tracegen_ref(params, n_cores, trace_len):
    """Reference trace tensor int32[n_cores, trace_len, 3]."""
    params = jnp.asarray(params, jnp.int32)
    u = lambda i: params[i].astype(jnp.uint32)

    seed = u(spec.P_SEED)
    pattern = u(spec.P_PATTERN)
    priv_lines = jnp.maximum(u(spec.P_PRIV_LINES), 1)
    shared_lines = jnp.maximum(u(spec.P_SHARED_LINES), 1)
    pct_shared = u(spec.P_PCT_SHARED)
    pct_w_sh = u(spec.P_PCT_WRITE_SHARED)
    pct_w_pr = u(spec.P_PCT_WRITE_PRIV)
    sync_kind = u(spec.P_SYNC_KIND)
    sync_period = u(spec.P_SYNC_PERIOD)
    crit_len = u(spec.P_CRIT_LEN)
    n_locks = jnp.maximum(u(spec.P_N_LOCKS), 1)
    gap_max = u(spec.P_COMPUTE_GAP)
    stride = jnp.maximum(u(spec.P_STRIDE), 1)
    grid_dim = jnp.maximum(u(spec.P_GRID_DIM), 1)
    barrier_period = u(spec.P_BARRIER_PERIOD)

    core = jax.lax.broadcasted_iota(jnp.uint32, (n_cores, trace_len), 0)
    slot = jax.lax.broadcasted_iota(jnp.uint32, (n_cores, trace_len), 1)

    h = [_mix_ref(seed, core, slot, jnp.uint32(k)) for k in range(7)]

    use_barriers = (sync_kind & 2) != 0
    bp = jnp.maximum(barrier_period, 1)
    is_barrier = use_barriers & (barrier_period > 0) & (((slot + 1) % bp) == 0)
    barrier_epoch = (slot + 1) // bp

    use_locks = (sync_kind & 1) != 0
    sp = jnp.maximum(sync_period, 1)
    crit_len = jnp.minimum(crit_len, sp - jnp.minimum(sp, 2))
    m = slot % sp
    episode_start = slot - m
    lock_id = _mix_ref(seed, core, episode_start, jnp.uint32(7)) % n_locks
    episode_end = episode_start + crit_len + 1
    fits = (episode_start >= 1) & (episode_end <= jnp.uint32(trace_len - 2))
    first_bar = bp * ((episode_start + bp) // bp) - 1
    no_bar_inside = jnp.logical_not(
        use_barriers & (barrier_period > 0) & (first_bar <= episode_end)
    )
    in_lock_mode = use_locks & (sync_period > 0) & fits & no_bar_inside
    is_lock = in_lock_mode & (m == 0)
    is_unlock = in_lock_mode & (m == crit_len + 1)
    is_crit = in_lock_mode & (m >= 1) & (m <= crit_len)
    lock_addr = jnp.uint32(spec.LOCK_BASE) + lock_id
    crit_addr = (
        jnp.uint32(spec.LOCK_DATA_BASE)
        + lock_id * jnp.uint32(spec.LOCK_DATA_SPAN)
        + h[3] % jnp.uint32(spec.LOCK_DATA_SPAN)
    )
    crit_store = (h[2] % jnp.uint32(1000)) < jnp.uint32(500)

    is_shared = (h[0] % jnp.uint32(1000)) < pct_shared
    sh_store = (h[1] % jnp.uint32(1000)) < pct_w_sh
    pr_store = (h[1] % jnp.uint32(1000)) < pct_w_pr

    s_uniform = h[5] % shared_lines
    part = jnp.maximum(shared_lines // jnp.uint32(n_cores), 1)
    s_strided_rd = (slot * stride + core) % shared_lines
    s_strided_wr = (core * part + (slot * stride) % part) % shared_lines
    s_strided = jnp.where(sh_store, s_strided_wr, s_strided_rd)
    blk = jnp.maximum(shared_lines // jnp.uint32(spec.N_BLOCKS), 1)
    own_block = core % jnp.uint32(spec.N_BLOCKS)
    rd_block = h[5] % jnp.uint32(spec.N_BLOCKS)
    block_sel = jnp.where(sh_store, own_block, rd_block)
    s_blocked = (block_sel * blk + h[6] % blk) % shared_lines
    row = core % grid_dim
    drow = h[5] % jnp.uint32(3)
    row2 = (row + grid_dim + drow - 1) % grid_dim
    row_sel = jnp.where(sh_store, row, row2)
    s_stencil = (row_sel * grid_dim + h[6] % grid_dim) % shared_lines
    hot = jnp.minimum(shared_lines, jnp.uint32(spec.HOT_SET_LINES))
    s_hot = h[5] % hot

    s = s_uniform
    s = jnp.where(pattern == 1, s_strided, s)
    s = jnp.where(pattern == 2, s_blocked, s)
    s = jnp.where(pattern == 3, s_stencil, s)
    s = jnp.where(pattern == 4, s_hot, s)
    shared_addr = jnp.uint32(spec.SHARED_BASE) + s

    hot_priv = jnp.maximum(priv_lines // jnp.uint32(8), 1)
    priv_idx = jnp.where(
        (h[6] % jnp.uint32(1000)) < jnp.uint32(800), h[3] % hot_priv, h[3] % priv_lines
    )
    priv_addr = (
        jnp.uint32(spec.PRIV_BASE)
        + core * jnp.uint32(spec.PRIV_STRIDE)
        + priv_idx
    )

    normal_store = jnp.where(is_shared, sh_store, pr_store)
    normal_addr = jnp.where(is_shared, shared_addr, priv_addr)
    normal_op = jnp.where(
        normal_store, jnp.uint32(spec.OP_STORE), jnp.uint32(spec.OP_LOAD)
    )

    op = normal_op
    addr = normal_addr
    op = jnp.where(
        is_crit,
        jnp.where(crit_store, jnp.uint32(spec.OP_STORE), jnp.uint32(spec.OP_LOAD)),
        op,
    )
    addr = jnp.where(is_crit, crit_addr, addr)
    op = jnp.where(is_unlock, jnp.uint32(spec.OP_UNLOCK), op)
    addr = jnp.where(is_unlock, lock_addr, addr)
    op = jnp.where(is_lock, jnp.uint32(spec.OP_LOCK), op)
    addr = jnp.where(is_lock, lock_addr, addr)
    op = jnp.where(is_barrier, jnp.uint32(spec.OP_BARRIER), op)
    addr = jnp.where(is_barrier, jnp.uint32(spec.BARRIER_BASE), addr)

    gap = h[4] % (gap_max + 1)
    is_memop = (op == spec.OP_LOAD) | (op == spec.OP_STORE)
    aux = jnp.where(is_memop, gap, jnp.uint32(0))
    aux = jnp.where(is_barrier, barrier_epoch, aux)

    return jnp.stack(
        [op.astype(jnp.int32), addr.astype(jnp.int32), aux.astype(jnp.int32)],
        axis=-1,
    )
