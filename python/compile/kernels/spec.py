"""Shared *data-format* constants for the tracegen kernel and its oracle.

This module defines the trace encoding contract between the python
compile path (L1 pallas kernel / L2 jax model) and the rust simulator
(rust/src/trace/decode.rs).  Only constants live here — the generation
logic is implemented twice (kernels/tracegen.py and kernels/ref.py) so
the pytest oracle is meaningful.

Trace tensor: int32[n_cores, trace_len, 3] with columns (op, addr, aux).

Opcodes
    OP_LOAD    = 0   load  `addr`                     (aux = compute gap)
    OP_STORE   = 1   store `addr`                     (aux = compute gap)
    OP_LOCK    = 2   acquire spin-lock at `addr`      (aux = 0)
    OP_UNLOCK  = 3   release spin-lock at `addr`      (aux = 0)
    OP_BARRIER = 4   global barrier                   (aux = epoch)

Addresses are 64-byte cacheline indices partitioned into disjoint
regions so the rust side can classify traffic:

    PRIV_BASE + core * PRIV_STRIDE + k   private per-core data
    SHARED_BASE + k                      shared heap
    LOCK_DATA_BASE + lock*64 + k         data protected by lock `lock`
    LOCK_BASE + lock                     lock words
    BARRIER_BASE (+1)                    barrier counter / sense lines

Parameter vector: int32[16]
    0  seed
    1  pattern_id     0 uniform | 1 strided | 2 blocked | 3 stencil | 4 hot
    2  priv_lines     per-core private working set (lines)
    3  shared_lines   shared heap size (lines)
    4  pct_shared     per-mille of non-sync slots touching shared heap
    5  pct_write_shared  per-mille of shared accesses that are stores
    6  pct_write_priv    per-mille of private accesses that are stores
    7  sync_kind      bit0 = locks, bit1 = barriers
    8  sync_period    slots per lock episode (0 = no locks)
    9  crit_len       accesses inside a critical section
    10 n_locks        distinct lock words
    11 compute_gap_max  aux = hash % (gap+1) idle cycles before the op
    12 stride         address stride for pattern 1
    13 grid_dim       stencil grid dimension for pattern 3
    14 barrier_period slots per barrier (0 = no barriers)
    15 reserved (must be 0)
"""

N_PARAMS = 16

OP_LOAD = 0
OP_STORE = 1
OP_LOCK = 2
OP_UNLOCK = 3
OP_BARRIER = 4

PRIV_STRIDE = 1 << 16
PRIV_BASE = 0
LOCK_DATA_BASE = 1 << 26
SHARED_BASE = 1 << 27
LOCK_BASE = 1 << 28
BARRIER_BASE = 1 << 29

# Lines of protected data per lock.
LOCK_DATA_SPAN = 64

# Parameter indices.
P_SEED = 0
P_PATTERN = 1
P_PRIV_LINES = 2
P_SHARED_LINES = 3
P_PCT_SHARED = 4
P_PCT_WRITE_SHARED = 5
P_PCT_WRITE_PRIV = 6
P_SYNC_KIND = 7
P_SYNC_PERIOD = 8
P_CRIT_LEN = 9
P_N_LOCKS = 10
P_COMPUTE_GAP = 11
P_STRIDE = 12
P_GRID_DIM = 13
P_BARRIER_PERIOD = 14
P_RESERVED = 15

# Blocked pattern (pattern_id == 2) uses a fixed number of blocks.
N_BLOCKS = 32

# Hot-set pattern (pattern_id == 4) cap.
HOT_SET_LINES = 64
