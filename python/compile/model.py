"""L2 JAX model: full workload-program synthesis on top of the L1 kernel.

`make_workload_fn(n_cores, trace_len)` returns the function that is
AOT-lowered (see aot.py): params int32[16] -> trace int32[n_cores,
trace_len, 3].  The L2 layer composes the Pallas tracegen kernel with
the program epilogue:

  * the final slot of every core is forced to a join BARRIER so the
    simulated benchmark has a well-defined completion time (the paper's
    throughput metric is benchmark cycles to completion);
  * the first slot of every core is forced to a private warm-up load so
    every core begins with a compulsory miss into its own region, like
    a real benchmark's stack/frame touch.

Shapes are static; one artifact per (n_cores, trace_len) configuration.
"""

import jax
import jax.numpy as jnp

from .kernels import spec
from .kernels.tracegen import tracegen


def _epilogue(trace, n_cores):
    """Force slot 0 to a private warm-up load and the last slot to a
    join barrier.

    Implemented with elementwise `where` masks rather than `.at[].set`
    scatters: the HLO-text interchange targets xla_extension 0.5.1,
    whose scatter lowering mis-executes the jax>=0.8 pattern (it wrote
    the scatter indices instead of the updates).
    """
    trace_len = trace.shape[1]
    core = jax.lax.broadcasted_iota(jnp.int32, trace.shape[:2], 0)
    slot = jax.lax.broadcasted_iota(jnp.int32, trace.shape[:2], 1)
    first = slot == 0
    last = slot == trace_len - 1

    op, addr, aux = trace[..., 0], trace[..., 1], trace[..., 2]
    warm_addr = jnp.int32(spec.PRIV_BASE) + core * jnp.int32(spec.PRIV_STRIDE)
    op = jnp.where(first, jnp.int32(spec.OP_LOAD), op)
    addr = jnp.where(first, warm_addr, addr)
    aux = jnp.where(first, 0, aux)
    op = jnp.where(last, jnp.int32(spec.OP_BARRIER), op)
    addr = jnp.where(last, jnp.int32(spec.BARRIER_BASE), addr)
    aux = jnp.where(last, 0, aux)
    return jnp.stack([op, addr, aux], axis=-1)


def make_workload_fn(n_cores, trace_len, *, interpret=True):
    """Build the AOT entry point for one (n_cores, trace_len) configuration."""

    def workload(params):
        trace = tracegen(params, n_cores, trace_len, interpret=interpret)
        # Return a flat int32[n_cores * trace_len * 3]: 1-D output has an
        # unambiguous buffer layout, so the rust PJRT client reads it
        # back in logical row-major order regardless of how XLA laid out
        # the 3-D tensor.
        return (_epilogue(trace, n_cores).reshape(-1),)

    return workload


def workload_ref(params, n_cores, trace_len):
    """Oracle for the full L2 model (kernel oracle + epilogue)."""
    from .kernels.ref import tracegen_ref

    return _epilogue(tracegen_ref(params, n_cores, trace_len), n_cores)
