"""AOT-lower the L2 workload model to HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`).  The text parser reassigns ids and
round-trips cleanly — see /opt/xla-example/gen_hlo.py.

One artifact per (n_cores, trace_len) configuration; a manifest.json
records the set so the rust side can discover them.

Usage: cd python && python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .kernels import spec
from .model import make_workload_fn

# (n_cores, trace_len) AOT configurations.  Core counts follow the
# paper's 16/64/256 sweep; the small ones serve tests and examples.
CONFIGS = [
    (2, 256),
    (4, 512),
    (16, 2048),
    (64, 4096),
    (256, 1024),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_name(n_cores: int, trace_len: int) -> str:
    return f"tracegen_c{n_cores}_l{trace_len}.hlo.txt"


def build_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"params_len": spec.N_PARAMS, "configs": []}
    for n_cores, trace_len in CONFIGS:
        fn = make_workload_fn(n_cores, trace_len)
        params_spec = jax.ShapeDtypeStruct((spec.N_PARAMS,), jax.numpy.int32)
        lowered = jax.jit(fn).lower(params_spec)
        text = to_hlo_text(lowered)
        name = artifact_name(n_cores, trace_len)
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["configs"].append(
            {"n_cores": n_cores, "trace_len": trace_len, "file": name}
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json ({len(CONFIGS)} configs)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Legacy single-file interface kept for the Makefile stamp target.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    build_all(out_dir or ".")
    if args.out and os.path.basename(args.out) not in os.listdir(out_dir):
        # Stamp file so `make` sees the target as built.
        with open(args.out, "w") as f:
            f.write("see manifest.json\n")


if __name__ == "__main__":
    main()
