"""Unit tests for the `tardis serve` reference clients.

No live server: the sync client gets a fake socket replaying recorded
server frames, the async client gets a plain ``asyncio.StreamReader``
fed the same bytes.  The frames mirror what `rust/src/serve/server.rs`
emits (kept in sync with `rust/tests/serve.rs`).
"""

import asyncio
import io

import pytest

from client import (
    SCHEMA,
    AsyncTardisClient,
    ProtocolError,
    ServerError,
    TardisClient,
    decode_frame,
    encode_frame,
    validate_payload,
)


def make_payload(batch_id="b1"):
    """A minimal but schema-shaped tardis-serve-v1 payload (2 points)."""
    return {
        "schema": SCHEMA,
        "batch_id": batch_id,
        "seed": 7,
        "n_points": 2,
        "workers": 4,
        "timing": {"wall_s": 0.25, "queue_depth_at_submit": 1},
        "columns": {
            "workload": ["fft", "barnes"],
            "variant": ["tardis", "msi"],
            "cores": [4, 4],
            "sim_cycles": [1000, 2000],
            "memops": [500, 900],
            "total_flits": [300, 700],
            "wall_s": [0.1, 0.15],
        },
    }


def recorded(frames_in):
    """Serialize server frames to the byte stream a socket would yield."""
    return b"".join(encode_frame(f) for f in frames_in)


class FakeSock:
    """Duck-typed socket: replays recorded bytes, records sends."""

    def __init__(self, server_frames):
        self.sent = []
        self._rfile = io.BytesIO(recorded(server_frames))
        self.closed = False

    def sendall(self, data):
        self.sent.append(data)

    def makefile(self, mode):
        assert mode == "rb"
        return self._rfile

    def close(self):
        self.closed = True

    def sent_frames(self):
        return [decode_frame(line) for line in b"".join(self.sent).splitlines()]


class TestFrames:
    def test_encode_decode_round_trip(self):
        frame = {"type": "sweep", "id": "b", "points": [{"workload": "fft"}]}
        wire = encode_frame(frame)
        assert wire.endswith(b"\n") and wire.count(b"\n") == 1
        assert decode_frame(wire) == frame

    def test_encode_rejects_untyped_frames(self):
        for bad in [None, [], "x", {}, {"type": 3}]:
            with pytest.raises(ProtocolError):
                encode_frame(bad)

    def test_decode_rejects_non_frames(self):
        for bad in [b"not json\n", b"[1,2]\n", b'{"no_type":1}\n', b'"str"\n']:
            with pytest.raises(ProtocolError):
                decode_frame(bad)

    def test_validate_payload_accepts_well_formed(self):
        cols = validate_payload(make_payload())
        assert cols["workload"] == ["fft", "barnes"]
        assert cols["sim_cycles"] == [1000, 2000]

    def test_validate_payload_rejects_schema_mismatch(self):
        p = make_payload()
        p["schema"] = "tardis-serve-v0"
        with pytest.raises(ProtocolError, match="schema mismatch"):
            validate_payload(p)

    def test_validate_payload_rejects_ragged_columns(self):
        p = make_payload()
        p["columns"]["sim_cycles"] = [1000]  # 1 value for 2 points
        with pytest.raises(ProtocolError, match="ragged column"):
            validate_payload(p)

    def test_validate_payload_rejects_missing_identity_column(self):
        p = make_payload()
        del p["columns"]["variant"]
        with pytest.raises(ProtocolError, match="missing column"):
            validate_payload(p)

    def test_validate_payload_rejects_non_list_column(self):
        p = make_payload()
        p["columns"]["cores"] = 4
        with pytest.raises(ProtocolError, match="not a list"):
            validate_payload(p)


class TestSyncClient:
    def test_full_session_replay(self):
        sock = FakeSock([
            {"type": "hello", "server": "tardis-serve", "schema": SCHEMA,
             "workers": 4},
            {"type": "pong"},
            {"type": "ack", "batch_id": "b1", "n_points": 2, "queue_depth": 1},
            {"type": "progress", "batch_id": "b1", "point": 0, "memops": 100},
            {"type": "point_done", "batch_id": "b1", "point": 0, "wall_s": 0.1},
            {"type": "point_done", "batch_id": "b1", "point": 1, "wall_s": 0.2},
            {"type": "result", "batch_id": "b1", "payload": make_payload()},
        ])
        c = TardisClient(sock=sock)
        assert c.hello()["workers"] == 4
        c.ping()
        bid = c.submit_sweep(
            [{"workload": "fft", "cores": 4},
             {"workload": "barnes", "cores": 4, "protocol": "msi"}],
            batch_id="b1", seed=7, progress_every=50)
        assert bid == "b1"
        events = list(c.iter_progress(bid))
        assert [e["type"] for e in events] == \
            ["progress", "point_done", "point_done"]
        cols = c.fetch_columns(bid)
        assert cols["sim_cycles"] == [1000, 2000]
        assert cols["variant"] == ["tardis", "msi"]
        c.close()
        assert sock.closed

        # The recorded requests are exactly the protocol's frames.
        sent = sock.sent_frames()
        assert [f["type"] for f in sent] == ["hello", "ping", "sweep"]
        sweep = sent[2]
        assert sweep["id"] == "b1" and sweep["seed"] == 7
        assert sweep["progress_every"] == 50
        assert sweep["points"][1]["protocol"] == "msi"

    def test_fetch_columns_skips_progress_chatter(self):
        sock = FakeSock([
            {"type": "ack", "batch_id": "b1", "n_points": 2, "queue_depth": 0},
            {"type": "progress", "batch_id": "b1", "point": 1, "memops": 5},
            {"type": "result", "batch_id": "b1", "payload": make_payload()},
        ])
        c = TardisClient(sock=sock)
        bid = c.submit_sweep([{"workload": "fft"}] * 2, batch_id="b1")
        assert c.fetch_columns(bid)["workload"] == ["fft", "barnes"]

    def test_server_error_frame_raises(self):
        sock = FakeSock([
            {"type": "ack", "batch_id": "b1", "n_points": 1, "queue_depth": 0},
            {"type": "error", "batch_id": "b1",
             "message": "point 0: unknown workload \"nope\""},
        ])
        c = TardisClient(sock=sock)
        bid = c.submit_sweep([{"workload": "nope"}], batch_id="b1")
        with pytest.raises(ServerError, match="unknown workload"):
            c.fetch_columns(bid)

    def test_rejected_sweep_raises_at_submit(self):
        sock = FakeSock([
            {"type": "error", "message": "unknown key \"corez\""},
        ])
        c = TardisClient(sock=sock)
        with pytest.raises(ServerError, match="corez"):
            c.submit_sweep([{"workload": "fft", "corez": 4}], batch_id="b1")

    def test_interleaved_batches_route_by_id(self):
        # b2's result arrives first; fetching b1 must buffer it.
        sock = FakeSock([
            {"type": "ack", "batch_id": "b1", "n_points": 2, "queue_depth": 0},
            {"type": "ack", "batch_id": "b2", "n_points": 2, "queue_depth": 1},
            {"type": "result", "batch_id": "b2", "payload": make_payload("b2")},
            {"type": "result", "batch_id": "b1", "payload": make_payload("b1")},
        ])
        c = TardisClient(sock=sock)
        b1 = c.submit_sweep([{"workload": "fft"}] * 2, batch_id="b1")
        b2 = c.submit_sweep([{"workload": "fft"}] * 2, batch_id="b2")
        p1 = c.fetch_payload(b1)
        p2 = c.fetch_payload(b2)
        assert p1["batch_id"] == "b1" and p2["batch_id"] == "b2"

    def test_eof_mid_stream_is_a_protocol_error(self):
        sock = FakeSock([
            {"type": "ack", "batch_id": "b1", "n_points": 1, "queue_depth": 0},
        ])
        c = TardisClient(sock=sock)
        bid = c.submit_sweep([{"workload": "fft"}], batch_id="b1")
        with pytest.raises(ProtocolError, match="closed"):
            c.fetch_columns(bid)

    def test_empty_sweep_rejected_client_side(self):
        c = TardisClient(sock=FakeSock([]))
        with pytest.raises(ProtocolError, match="non-empty"):
            c.submit_sweep([], batch_id="b1")


class FakeWriter:
    """Duck-typed asyncio writer recording frames."""

    def __init__(self):
        self.sent = []
        self.closed = False

    def write(self, data):
        self.sent.append(data)

    def close(self):
        self.closed = True


def make_async_client(server_frames):
    reader = asyncio.StreamReader()
    reader.feed_data(recorded(server_frames))
    reader.feed_eof()
    return AsyncTardisClient(reader, FakeWriter())


class TestAsyncClient:
    def test_full_session_replay(self):
        async def scenario():
            c = make_async_client([
                {"type": "hello", "server": "tardis-serve", "schema": SCHEMA,
                 "workers": 2},
                {"type": "ack", "batch_id": "b1", "n_points": 2,
                 "queue_depth": 0},
                {"type": "progress", "batch_id": "b1", "point": 0,
                 "memops": 10},
                {"type": "result", "batch_id": "b1",
                 "payload": make_payload()},
            ])
            assert (await c.hello())["workers"] == 2
            bid = await c.submit_sweep(
                [{"workload": "fft"}] * 2, batch_id="b1")
            events = [e async for e in c.iter_progress(bid)]
            assert [e["type"] for e in events] == ["progress"]
            cols = await c.fetch_columns(bid)
            assert cols["sim_cycles"] == [1000, 2000]
            await c.close()

        asyncio.run(scenario())

    def test_error_frame_raises(self):
        async def scenario():
            c = make_async_client([
                {"type": "ack", "batch_id": "b1", "n_points": 1,
                 "queue_depth": 0},
                {"type": "error", "batch_id": "b1", "message": "boom"},
            ])
            bid = await c.submit_sweep([{"workload": "fft"}], batch_id="b1")
            with pytest.raises(ServerError, match="boom"):
                await c.fetch_columns(bid)

        asyncio.run(scenario())
