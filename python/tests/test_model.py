"""L2 model tests: epilogue semantics, shapes, and model-vs-oracle."""

import numpy as np

import jax.numpy as jnp

from compile.kernels import spec
from compile.model import make_workload_fn, workload_ref
from .test_kernel import make_params


class TestModel:
    def test_output_shape(self):
        fn = make_workload_fn(4, 256)
        (t,) = fn(make_params())
        assert t.shape == (4 * 256 * 3,)
        assert t.dtype == jnp.int32

    def test_model_matches_ref(self):
        p = make_params(seed=9, pattern=2, sync_kind=1, sync_period=32)
        (t,) = make_workload_fn(4, 256)(p)
        ref = workload_ref(p, 4, 256)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(ref).reshape(-1))

    def test_final_slot_is_join_barrier(self):
        (t,) = make_workload_fn(4, 256)(make_params())
        t = np.asarray(t).reshape(4, 256, 3)
        assert (t[:, -1, 0] == spec.OP_BARRIER).all()
        assert (t[:, -1, 1] == spec.BARRIER_BASE).all()

    def test_first_slot_is_private_warmup(self):
        (t,) = make_workload_fn(4, 256)(make_params())
        t = np.asarray(t).reshape(4, 256, 3)
        assert (t[:, 0, 0] == spec.OP_LOAD).all()
        for c in range(4):
            assert t[c, 0, 1] == c * spec.PRIV_STRIDE

    def test_barrier_count_balanced_across_cores(self):
        # Barriers come from slot arithmetic only, so every core must
        # emit the same number — required for the barrier not to hang.
        p = make_params(sync_kind=2, barrier_period=32)
        (t,) = make_workload_fn(8, 256)(p)
        t = np.asarray(t).reshape(8, 256, 3)
        counts = (t[..., 0] == spec.OP_BARRIER).sum(axis=1)
        assert (counts == counts[0]).all()

    def test_no_barrier_inside_critical_section(self):
        # A core must never hold a lock while waiting at a barrier
        # (deadlock with a core spinning on that lock).
        p = make_params(sync_kind=3, sync_period=16, crit_len=3,
                        barrier_period=24)
        (t,) = make_workload_fn(4, 512)(p)
        t = np.asarray(t).reshape(4, 512, 3)
        for c in range(4):
            held = False
            for op in t[c, :, 0]:
                if op == spec.OP_LOCK:
                    held = True
                elif op == spec.OP_UNLOCK:
                    held = False
                elif op == spec.OP_BARRIER:
                    assert not held

    def test_every_lock_is_released_before_trace_end(self):
        p = make_params(sync_kind=1, sync_period=16, crit_len=3)
        for tl in (256, 512):
            (t,) = make_workload_fn(4, tl)(p)
            t = np.asarray(t).reshape(4, tl, 3)
            for c in range(4):
                held = {}
                for op, addr in zip(t[c, :, 0], t[c, :, 1]):
                    if op == spec.OP_LOCK:
                        assert not held.get(addr, False), "nested lock"
                        held[addr] = True
                    elif op == spec.OP_UNLOCK:
                        assert held.get(addr, False), "orphan unlock"
                        held[addr] = False
                assert not any(held.values()), "lock held at join barrier"

    def test_lock_depth_never_negative_or_above_one(self):
        # LOCK/UNLOCK alternate per core: depth stays in {0, 1}.
        p = make_params(sync_kind=1, sync_period=16, crit_len=3)
        (t,) = make_workload_fn(4, 512)(p)
        t = np.asarray(t).reshape(4, 512, 3)
        for c in range(4):
            depth = 0
            for op in t[c, :, 0]:
                if op == spec.OP_LOCK:
                    depth += 1
                elif op == spec.OP_UNLOCK:
                    depth -= 1
                assert 0 <= depth <= 1
