"""Kernel-vs-oracle tests: the CORE correctness signal for the L1 layer.

The pallas tracegen kernel (interpret=True) must produce bit-identical
output to the whole-array jnp oracle in kernels/ref.py for every shape
and parameter vector.  Hypothesis sweeps both.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import spec
from compile.kernels.ref import tracegen_ref
from compile.kernels.tracegen import tracegen


def make_params(
    seed=1,
    pattern=0,
    priv_lines=64,
    shared_lines=256,
    pct_shared=300,
    pct_write_shared=200,
    pct_write_priv=300,
    sync_kind=0,
    sync_period=0,
    crit_len=4,
    n_locks=16,
    compute_gap=4,
    stride=3,
    grid_dim=8,
    barrier_period=0,
):
    p = np.zeros(spec.N_PARAMS, np.int32)
    p[spec.P_SEED] = seed
    p[spec.P_PATTERN] = pattern
    p[spec.P_PRIV_LINES] = priv_lines
    p[spec.P_SHARED_LINES] = shared_lines
    p[spec.P_PCT_SHARED] = pct_shared
    p[spec.P_PCT_WRITE_SHARED] = pct_write_shared
    p[spec.P_PCT_WRITE_PRIV] = pct_write_priv
    p[spec.P_SYNC_KIND] = sync_kind
    p[spec.P_SYNC_PERIOD] = sync_period
    p[spec.P_CRIT_LEN] = crit_len
    p[spec.P_N_LOCKS] = n_locks
    p[spec.P_COMPUTE_GAP] = compute_gap
    p[spec.P_STRIDE] = stride
    p[spec.P_GRID_DIM] = grid_dim
    p[spec.P_BARRIER_PERIOD] = barrier_period
    return jnp.asarray(p)


def assert_kernel_matches_ref(params, n_cores, trace_len):
    out = np.asarray(tracegen(params, n_cores, trace_len))
    ref = np.asarray(tracegen_ref(params, n_cores, trace_len))
    np.testing.assert_array_equal(out, ref)


# ---------------------------------------------------------------- basic


class TestKernelVsRef:
    def test_default_params(self):
        assert_kernel_matches_ref(make_params(), 4, 256)

    @pytest.mark.parametrize("pattern", [0, 1, 2, 3, 4])
    def test_every_pattern(self, pattern):
        assert_kernel_matches_ref(make_params(pattern=pattern), 4, 256)

    @pytest.mark.parametrize("n_cores,trace_len", [(2, 128), (4, 256), (8, 128), (16, 384)])
    def test_shapes(self, n_cores, trace_len):
        assert_kernel_matches_ref(make_params(), n_cores, trace_len)

    def test_locks_enabled(self):
        assert_kernel_matches_ref(
            make_params(sync_kind=1, sync_period=32, crit_len=4), 4, 256
        )

    def test_barriers_enabled(self):
        assert_kernel_matches_ref(
            make_params(sync_kind=2, barrier_period=64), 4, 256
        )

    def test_locks_and_barriers(self):
        assert_kernel_matches_ref(
            make_params(sync_kind=3, sync_period=16, crit_len=2, barrier_period=64),
            4,
            256,
        )

    def test_degenerate_params_clamped(self):
        # zero-sized regions must not divide by zero
        assert_kernel_matches_ref(
            make_params(priv_lines=0, shared_lines=0, n_locks=0, stride=0, grid_dim=0),
            2,
            128,
        )

    def test_multi_row_block_grid(self):
        # n_cores > 8 exercises the row-block dimension of the grid
        assert_kernel_matches_ref(make_params(seed=7), 16, 256)


# ------------------------------------------------------- trace semantics


class TestTraceSemantics:
    def test_opcodes_in_range(self):
        t = np.asarray(tracegen(make_params(sync_kind=3, sync_period=16,
                                            barrier_period=32), 4, 256))
        assert t[..., 0].min() >= spec.OP_LOAD
        assert t[..., 0].max() <= spec.OP_BARRIER

    def test_lock_unlock_pair_same_address(self):
        t = np.asarray(tracegen(make_params(sync_kind=1, sync_period=16,
                                            crit_len=3), 4, 256))
        op, addr = t[..., 0], t[..., 1]
        for c in range(4):
            locks = np.where(op[c] == spec.OP_LOCK)[0]
            for i in locks:
                j = i + 4  # crit_len + 1
                if j < 256 and op[c, j] == spec.OP_UNLOCK:
                    assert addr[c, i] == addr[c, j]

    def test_every_episode_unlock_matches_lock(self):
        sp, cl = 16, 3
        t = np.asarray(tracegen(make_params(sync_kind=1, sync_period=sp,
                                            crit_len=cl), 2, 256))
        op = t[..., 0]
        for c in range(2):
            # Episode at slot 0 is suppressed (warm-up guard); every
            # later episode that fits before the join barrier is full.
            for start in range(sp, 256 - sp, sp):
                assert op[c, start] == spec.OP_LOCK
                assert op[c, start + cl + 1] == spec.OP_UNLOCK

    def test_private_addresses_disjoint_across_cores(self):
        t = np.asarray(tracegen(make_params(pct_shared=0), 4, 256))
        addr = t[..., 1]
        priv = (addr < spec.LOCK_DATA_BASE)
        for c in range(4):
            a = addr[c][priv[c]]
            assert (a // spec.PRIV_STRIDE == c).all()

    def test_shared_fraction_tracks_param(self):
        t = np.asarray(tracegen(make_params(pct_shared=500, sync_kind=0),
                                8, 1024))
        addr = t[..., 1]
        shared = ((addr >= spec.SHARED_BASE) & (addr < spec.LOCK_BASE)).mean()
        assert 0.40 < shared < 0.60

    def test_write_fraction_tracks_param(self):
        t = np.asarray(tracegen(make_params(pct_shared=1000,
                                            pct_write_shared=250), 8, 1024))
        stores = (t[..., 0] == spec.OP_STORE).mean()
        assert 0.18 < stores < 0.32

    def test_hot_pattern_small_footprint(self):
        t = np.asarray(tracegen(make_params(pattern=4, pct_shared=1000,
                                            shared_lines=4096), 4, 512))
        addr = t[..., 1]
        sh = addr[(addr >= spec.SHARED_BASE) & (addr < spec.LOCK_BASE)]
        assert len(np.unique(sh)) <= spec.HOT_SET_LINES

    def test_blocked_pattern_writes_own_block(self):
        t = np.asarray(tracegen(make_params(pattern=2, pct_shared=1000,
                                            pct_write_shared=500,
                                            shared_lines=1024), 4, 512))
        op, addr = t[..., 0], t[..., 1]
        blk = 1024 // spec.N_BLOCKS
        for c in range(4):
            w = addr[c][(op[c] == spec.OP_STORE)] - spec.SHARED_BASE
            if len(w):
                assert ((w // blk) % spec.N_BLOCKS == c % spec.N_BLOCKS).all()

    def test_deterministic(self):
        p = make_params(seed=42)
        a = np.asarray(tracegen(p, 4, 256))
        b = np.asarray(tracegen(p, 4, 256))
        np.testing.assert_array_equal(a, b)

    def test_seed_changes_trace(self):
        a = np.asarray(tracegen(make_params(seed=1), 4, 256))
        b = np.asarray(tracegen(make_params(seed=2), 4, 256))
        assert (a != b).any()

    def test_compute_gap_bounded(self):
        t = np.asarray(tracegen(make_params(compute_gap=7), 4, 256))
        memop = (t[..., 0] == spec.OP_LOAD) | (t[..., 0] == spec.OP_STORE)
        assert t[..., 2][memop].max() <= 7
        assert t[..., 2][memop].min() >= 0


# ----------------------------------------------------------- hypothesis


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    pattern=st.integers(0, 4),
    pct_shared=st.integers(0, 1000),
    pct_w=st.integers(0, 1000),
    priv_lines=st.integers(0, 2048),
    shared_lines=st.integers(0, 8192),
)
def test_hypothesis_params_match_ref(seed, pattern, pct_shared, pct_w,
                                     priv_lines, shared_lines):
    p = make_params(seed=seed, pattern=pattern, pct_shared=pct_shared,
                    pct_write_shared=pct_w, priv_lines=priv_lines,
                    shared_lines=shared_lines)
    assert_kernel_matches_ref(p, 4, 128)


@settings(max_examples=15, deadline=None)
@given(
    n_cores=st.sampled_from([2, 4, 8, 16]),
    n_blocks_len=st.integers(1, 4),
    sync_kind=st.integers(0, 3),
    sync_period=st.sampled_from([0, 8, 16, 40]),
    barrier_period=st.sampled_from([0, 16, 50]),
)
def test_hypothesis_shapes_and_sync_match_ref(n_cores, n_blocks_len,
                                              sync_kind, sync_period,
                                              barrier_period):
    p = make_params(sync_kind=sync_kind, sync_period=sync_period,
                    crit_len=3, barrier_period=barrier_period)
    assert_kernel_matches_ref(p, n_cores, 128 * n_blocks_len)
